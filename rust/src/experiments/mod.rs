//! Experiment harness: the shared measurement layer that computes, for a
//! (dataset, strategy, AutoML searcher, repetition) cell, the paper's
//! two metrics:
//!
//! * Time-Reduction = 1 − Time(M_sub) / Time(M*)
//! * Relative-Accuracy = Acc(M_sub) / Acc(M*)
//!
//! where Time(M_sub) covers the entire SubStrat flow (subset search +
//! AutoML on the subset + restricted fine-tune), Time(M*) covers the
//! Full-AutoML search, and accuracies are measured on a held-out
//! stratified test split. The final refits behind both accuracies sit
//! *outside* both timed windows.
//!
//! Scheduling, timing discipline, and resume live in [`runner`]
//! (DESIGN.md §5.2): every table/figure driver expands its grid into
//! [`runner::Cell`]s and hands them to [`runner::Runner`]; the
//! search/finish split below (`full_search`/`finish_full`,
//! `strategy_search`/`finish_strategy`) exists so the runner owns the
//! stopwatch around exactly the window the paper times.

pub mod bench;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod runner;
pub mod table4;

use std::path::PathBuf;

pub use runner::TimingMode;

use crate::automl::{eval::fit_on_frame, run_automl, AutoMlConfig, AutoMlResult, SearcherKind};
use crate::baselines::{self, StrategyOutcome};
use crate::data::{registry, registry::DataSource, split, CodeMatrix, Frame};
use crate::gendst::pareto::Objective;
use crate::measures::entropy::EntropyMeasure;
use crate::substrat::{run_substrat, SubStratConfig, SubStratRun};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Experiment-wide parameters (CLI-settable).
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// row-count multiplier vs the paper's Table-2 shapes (1.0 = full)
    pub scale: f64,
    /// row floor after scaling (subsets of sqrt(N) rows need N large
    /// enough for CV to rank model families; never exceeds the paper N)
    pub min_rows: usize,
    /// row cap after scaling (bounds the single-core cost of D10)
    pub max_rows: usize,
    // fp-exempt: a cell *coordinate*, not a computation knob — the rep
    // index is part of each cell's own journal key (Cell::fingerprint)
    /// repetitions per cell (paper: 5)
    pub reps: usize,
    /// full-AutoML evaluation budget (each = one CV'd pipeline fit)
    pub full_evals: usize,
    /// fine-tune budget fraction (paper: "restricted, much shorter")
    pub ft_frac: f64,
    // fp-exempt: cell coordinate — the searcher name is in each cell's
    // journal key, so narrowing the sweep must not rotate shared cells
    pub searchers: Vec<SearcherKind>,
    // fp-exempt: cell coordinate — the symbol plus its DataSource
    // content fingerprint key each cell (DESIGN.md §5.3), so a sweep
    // over fewer datasets still resumes the overlap
    /// dataset specs: Table-2 symbols (`D1`..`D10`) and/or CSV paths,
    /// resolved per cell by [`DataSource::parse`] (DESIGN.md §5.3)
    pub datasets: Vec<String>,
    /// CSV sources only: target column (name or 0-based index;
    /// `None` = last column). Feeds the config fingerprint — changing
    /// the target changes what every cell computes.
    pub csv_target: Option<String>,
    /// CSV sources only: force the header decision (`None` = the
    /// [`crate::data::csv::detect_header`] heuristic)
    pub csv_header: Option<bool>,
    // fp-exempt: where results land, never what they contain
    pub out_dir: PathBuf,
    // fp-exempt: pure speed — records must survive a re-run on
    // different hardware (Wall results are thread-invariant by test)
    /// total hardware thread budget for the sweep; the runner splits it
    /// into outer cell workers × inner engine threads (never threads²)
    pub threads: usize,
    /// Gen-DST islands per strategy cell (DESIGN.md §4.6). Pinned
    /// explicitly and fed to the config fingerprint — never derived
    /// from the thread budget, so records stay bit-identical across
    /// `--threads`/machines; always ≥ 1 (the CLI clamps 0 up). The
    /// default 1 is the paper's single-population engine.
    pub islands: usize,
    /// Gen-DST objective vector (DESIGN.md §10). `[Fidelity]` is the
    /// paper's scalar engine; adding `SubsetSize`/`DownstreamTime`
    /// switches strategy cells to the NSGA-II path, which changes the
    /// search trajectory and therefore feeds the config fingerprint.
    pub objectives: Vec<Objective>,
    /// multi-objective runs only: per-objective weights picking the
    /// operating point on the returned front (`None` = fidelity
    /// extreme, i.e. the scalar winner). Changes which subset every
    /// strategy cell trains on, so it feeds the config fingerprint.
    pub operating_point: Option<Vec<f64>>,
    /// proposals per AutoML engine round — a fixed schedule, never
    /// derived from the thread budget, so the search trajectory (and
    /// with it every record) is identical at any thread count
    pub batch: usize,
    /// how cell times are measured (DESIGN.md §5.2); only `Wall` may
    /// report paper Time-Reduction
    pub timing: TimingMode,
    // fp-exempt: toggles persistence of results, not their values
    /// append finished cells to `<out_dir>/cells.jsonl` and skip
    /// already-journaled cells on re-run
    pub journal: bool,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale: 0.15,
            min_rows: 6_000,
            max_rows: 15_000,
            reps: 2,
            full_evals: 14,
            ft_frac: 0.2,
            searchers: vec![SearcherKind::Smbo, SearcherKind::Gp],
            datasets: registry::all_symbols().iter().map(|s| s.to_string()).collect(),
            csv_target: None,
            csv_header: None,
            out_dir: PathBuf::from("results"),
            threads: crate::util::pool::default_threads(),
            islands: 1,
            objectives: vec![Objective::Fidelity],
            operating_point: None,
            batch: 8,
            timing: TimingMode::Wall,
            journal: true,
            seed: 20220,
        }
    }
}

/// The Full-AutoML reference for one (dataset, searcher, rep).
pub struct FullRun {
    pub elapsed_s: f64,
    pub test_acc: f64,
    pub best_desc: String,
}

/// One measured cell.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub dataset: String,
    pub strategy: String,
    pub searcher: &'static str,
    pub rep: usize,
    pub time_full_s: f64,
    pub time_sub_s: f64,
    pub acc_full: f64,
    pub acc_sub: f64,
    /// describe() of the final configuration M_sub (debug/analysis aid)
    pub final_desc: String,
}

impl RunRecord {
    pub fn time_reduction(&self) -> f64 {
        1.0 - self.time_sub_s / self.time_full_s.max(1e-9)
    }

    pub fn relative_accuracy(&self) -> f64 {
        self.acc_sub / self.acc_full.max(1e-9)
    }
}

/// The single mode-matching subtraction of a strategy's setup overhead
/// (MC-24H's budget-estimation probe) from a measured cell window. The
/// subtrahend must be measured on the same clock as the window — wall
/// setup from a wall window, CPU setup from a CPU-proxy window — and
/// this function is the ONLY place the subtraction happens:
/// `SubStratRun.total_time_s` is deliberately raw (the seed subtracted
/// there *and* in the runner, double-counting MC-24H's probe; regression
/// `mc24h_setup_is_subtracted_exactly_once` below and the raw-total test
/// in `substrat`).
pub fn charged_time_s(elapsed_s: f64, outcome: &StrategyOutcome, timing: TimingMode) -> f64 {
    let setup = match timing {
        TimingMode::Wall => outcome.setup_s,
        TimingMode::CpuProxy => outcome.setup_cpu_s,
    };
    (elapsed_s - setup).max(0.0)
}

/// Prepared per-(dataset, rep) state shared by all strategies.
pub struct Prepared {
    pub train: Frame,
    pub test: Frame,
    pub codes: CodeMatrix,
}

/// The experiment-wide CSV ingestion options (DESIGN.md §5.3).
fn csv_opts(cfg: &ExpConfig) -> crate::data::infer::CsvOptions {
    crate::data::infer::CsvOptions {
        header: cfg.csv_header,
        target: cfg.csv_target.clone(),
        ..Default::default()
    }
}

/// Ingest the full frame behind a CSV spec together with the journal
/// fingerprint of the very bytes ingested (`None` for registry
/// symbols, which generate per rep). The runner pre-loads each
/// distinct CSV **once** and hands the frame back to [`prepare_from`]
/// per group — without this an overnight sweep re-reads and re-infers
/// the whole file for every (rep, searcher) group.
///
/// Returning the fingerprint *from ingestion* closes the PR 4 race:
/// the journal key used to come from a separate earlier read of the
/// file, so an edit landing between that read and ingestion journaled
/// fresh results under the stale hash. The key now provably describes
/// the content the cells ran on (`CsvSummary::content_fp`, hashed on
/// the ingestion passes themselves and formatted exactly like
/// [`DataSource::fingerprint`]'s `csv:<hex>` keys — existing journals
/// stay valid).
pub fn ingest_source(spec: &str, cfg: &ExpConfig) -> Option<(Frame, String)> {
    match DataSource::parse(spec) {
        DataSource::Csv { path } => {
            let (full, summary) = crate::data::infer::load_csv_frame(&path, &csv_opts(cfg))
                .unwrap_or_else(|e| panic!("ingesting {}: {e}", path.display()));
            let fp = format!("csv:{}", crate::util::hash::hex128(summary.content_fp));
            Some((full, fp))
        }
        DataSource::Table2 { .. } => None,
    }
}

/// Load + split + encode one dataset spec (a Table-2 symbol or a CSV
/// path, resolved by [`DataSource::parse`]) at the experiment scale.
///
/// Registry sources scale their synthetic row counts with the row
/// floor/cap applied (the floor never exceeds the paper's own N). A CSV
/// source has exactly the rows the file has: `scale`/`min_rows` cannot
/// create data, so only the `max_rows` cap applies — a deterministic
/// seeded row subsample, varied per rep like the synth seeds are, and
/// warned about loudly whenever it actually truncates.
pub fn prepare(spec: &str, cfg: &ExpConfig, rep: usize) -> Prepared {
    prepare_from(spec, cfg, rep, None)
}

/// [`prepare`] with an optionally pre-ingested full CSV frame (see
/// [`ingest_source`]); `preloaded` is ignored for registry specs.
pub fn prepare_from(
    spec: &str,
    cfg: &ExpConfig,
    rep: usize,
    preloaded: Option<&Frame>,
) -> Prepared {
    // Cow: a pre-ingested, uncapped CSV frame is only borrowed (the
    // runner's cache would otherwise be deep-copied per group — in
    // CpuProxy mode concurrently)
    let frame: std::borrow::Cow<Frame> = match DataSource::parse(spec) {
        DataSource::Table2 { symbol } => {
            let mut synth = registry::spec_for(
                &symbol,
                cfg.scale,
                cfg.seed ^ (rep as u64).wrapping_mul(0x9e37),
            );
            let paper_rows = registry::table2()
                .into_iter()
                .find(|d| d.symbol == symbol)
                .map(|d| d.n_rows)
                .unwrap_or(synth.n_rows);
            synth.n_rows = synth
                .n_rows
                .max(cfg.min_rows.min(paper_rows))
                .min(cfg.max_rows.max(2));
            std::borrow::Cow::Owned(synth.generate())
        }
        DataSource::Csv { path } => {
            let full: std::borrow::Cow<Frame> = match preloaded {
                Some(f) => std::borrow::Cow::Borrowed(f),
                None => {
                    let (full, _) =
                        crate::data::infer::load_csv_frame(&path, &csv_opts(cfg))
                            .unwrap_or_else(|e| {
                                panic!("ingesting {}: {e}", path.display())
                            });
                    std::borrow::Cow::Owned(full)
                }
            };
            let cap = cfg.max_rows.max(2);
            if full.n_rows > cap {
                // never cap silently: a D10-shaped file trimmed to the
                // default max_rows would otherwise report results for a
                // fraction of the data without saying so
                eprintln!(
                    "[prepare] {}: capping {} file rows to --max-rows {cap} \
                     (seeded subsample; raise --max-rows to use more)",
                    full.name, full.n_rows
                );
                let mut rng = Rng::new(cfg.seed ^ 0x9c1 ^ rep as u64);
                let mut rows = rng.sample_distinct(full.n_rows, cap);
                rows.sort_unstable();
                let cols: Vec<u32> = (0..full.n_cols() as u32).collect();
                std::borrow::Cow::Owned(full.subset(&rows, &cols))
            } else {
                full
            }
        }
    };
    let mut rng = Rng::new(cfg.seed ^ 0xabc ^ rep as u64);
    let (train, test) = split::train_test_split(&frame, 0.25, &mut rng);
    let codes = CodeMatrix::from_frame(&train);
    Prepared { train, test, codes }
}

/// Wire one AutoML configuration into the cell's thread allowance: the
/// evaluation engine fans each proposal batch across `inner_threads`
/// workers, while the batch size stays the *fixed* `cfg.batch` schedule.
/// Deriving the batch from the thread count (as the seed did) changes
/// which history the SMBO/GP searchers see per round, so the winner
/// depended on the machine's core count; a fixed batch makes threads
/// pure speed. Applied identically to the Full-AutoML reference and
/// every strategy cell, so the time-reduction ratio compares like with
/// like.
fn wire_engine(automl: &mut AutoMlConfig, cfg: &ExpConfig, inner_threads: usize) {
    automl.policy.threads = inner_threads.max(1);
    automl.batch_size = cfg.batch.max(1);
}

/// The timed region of the Full-AutoML reference: the search
/// `A(D, y) -> M*` alone. The caller (the runner, or [`run_full`])
/// wraps this in the stopwatch appropriate to its `TimingMode`.
pub fn full_search(
    prep: &Prepared,
    searcher: SearcherKind,
    cfg: &ExpConfig,
    rep: usize,
    inner_threads: usize,
) -> AutoMlResult {
    let mut automl = AutoMlConfig::new(searcher, cfg.full_evals, cfg.seed ^ rep as u64);
    wire_engine(&mut automl, cfg, inner_threads);
    run_automl(&prep.train, &automl)
}

/// Untimed tail of the Full-AutoML reference: refit `M*` on the train
/// split and score the holdout. The refit used to run *inside* the full
/// reference's timed window while every strategy's refit ran outside
/// its own, asymmetrically inflating Time(M*) and with it every
/// Time-Reduction figure.
pub fn finish_full(
    prep: &Prepared,
    res: &AutoMlResult,
    cfg: &ExpConfig,
    rep: usize,
    elapsed_s: f64,
) -> FullRun {
    let mut rng = Rng::new(cfg.seed ^ 0x77 ^ rep as u64);
    let pipe = fit_on_frame(&res.best, &prep.train, &mut rng);
    FullRun {
        elapsed_s,
        test_acc: pipe.accuracy_on(&prep.test),
        best_desc: res.best.describe(),
    }
}

/// Run the Full-AutoML reference: `A(D, y) -> M*`, wall-timed, tested.
pub fn run_full(prep: &Prepared, searcher: SearcherKind, cfg: &ExpConfig, rep: usize) -> FullRun {
    let sw = Stopwatch::start();
    let res = full_search(prep, searcher, cfg, rep, pool::resolve_threads(cfg.threads));
    let elapsed_s = sw.elapsed_s();
    finish_full(prep, &res, cfg, rep, elapsed_s)
}

/// The timed region of one strategy cell: the full SubStrat flow
/// (subset search + AutoML on the subset + restricted fine-tune).
/// Strategy "substrat-nf" = Gen-DST without the fine-tune pass; every
/// other name resolves via `baselines::by_name_threaded`, which keeps
/// the strategy's own parallelism inside `inner_threads`.
#[allow(clippy::too_many_arguments)]
pub fn strategy_search(
    prep: &Prepared,
    strategy_name: &str,
    searcher: SearcherKind,
    cfg: &ExpConfig,
    rep: usize,
    dst_size: Option<(usize, usize)>,
    ft_frac: f64,
    inner_threads: usize,
) -> SubStratRun {
    let (resolved, fine_tune) = match strategy_name {
        "substrat-nf" => ("gendst", false),
        other => (other, true),
    };
    // the cell's pinned island count and objective vector ride along
    // with its thread allowance — including into the MC-24H budget
    // probe, which must cost out the same engine shape the real
    // Gen-DST cell runs
    let strategy = baselines::by_name_configured(
        resolved,
        inner_threads.max(1),
        cfg.islands.max(1),
        &cfg.objectives,
    );
    let mut automl = AutoMlConfig::new(searcher, cfg.full_evals, cfg.seed ^ 0x33 ^ rep as u64);
    wire_engine(&mut automl, cfg, inner_threads);
    let sub_cfg = SubStratConfig {
        dst_size,
        fine_tune,
        fine_tune_frac: ft_frac,
        operating_point: cfg.operating_point.clone(),
        seed: cfg.seed ^ 0x44 ^ rep as u64,
    };
    run_substrat(
        &prep.train,
        &prep.codes,
        &EntropyMeasure,
        strategy.as_ref(),
        &automl,
        &sub_cfg,
    )
}

/// Untimed tail of a strategy cell: refit M_sub, score the holdout,
/// assemble the record (applied identically to Full-AutoML via
/// [`finish_full`]).
#[allow(clippy::too_many_arguments)]
pub fn finish_strategy(
    prep: &Prepared,
    symbol: &str,
    strategy_name: &str,
    searcher: SearcherKind,
    full: &FullRun,
    cfg: &ExpConfig,
    rep: usize,
    run: &SubStratRun,
    time_sub_s: f64,
) -> RunRecord {
    let mut rng = Rng::new(cfg.seed ^ 0x55 ^ rep as u64);
    let pipe = fit_on_frame(&run.final_config, &prep.train, &mut rng);
    let acc_sub = pipe.accuracy_on(&prep.test);
    RunRecord {
        dataset: symbol.to_string(),
        strategy: strategy_name.to_string(),
        searcher: searcher.name(),
        rep,
        time_full_s: full.elapsed_s,
        time_sub_s,
        acc_full: full.test_acc,
        acc_sub,
        final_desc: run.final_config.describe(),
    }
}

/// Run one strategy cell end to end, wall-timed (the runner drives the
/// split pieces itself so it can substitute CPU-proxy timing).
#[allow(clippy::too_many_arguments)]
pub fn run_strategy(
    prep: &Prepared,
    symbol: &str,
    strategy_name: &str,
    searcher: SearcherKind,
    full: &FullRun,
    cfg: &ExpConfig,
    rep: usize,
    dst_size: Option<(usize, usize)>,
) -> RunRecord {
    let run = strategy_search(
        prep,
        strategy_name,
        searcher,
        cfg,
        rep,
        dst_size,
        cfg.ft_frac,
        pool::resolve_threads(cfg.threads),
    );
    // total_time_s is raw wall clock; the paper window excludes the
    // strategy's setup overhead via the single subtraction site
    let time_sub_s = charged_time_s(run.total_time_s, &run.outcome, TimingMode::Wall);
    finish_strategy(prep, symbol, strategy_name, searcher, full, cfg, rep, &run, time_sub_s)
}

/// All Table-4 strategy rows including the SubStrat-NF flag variant.
pub fn table4_strategy_names() -> Vec<&'static str> {
    let mut v = vec!["gendst", "substrat-nf"];
    v.extend(baselines::table4_strategies().into_iter().filter(|&s| s != "gendst"));
    v
}

/// Pretty strategy label matching the paper's names.
pub fn paper_label(strategy: &str) -> &'static str {
    match strategy {
        "gendst" => "SubStrat",
        "substrat-nf" => "SubStrat-NF",
        "ig-km" => "IG-KM",
        "ig-rand" => "IG-Rand",
        "mab" => "MAB",
        "km" => "KM",
        "mc-100" => "MC-100",
        "mc-100k" => "MC-100K",
        "mc-24h" => "MC-24H",
        "greedy-seq" => "Greedy-Seq",
        "greedy-mult" => "Greedy-Mult",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            scale: 0.02,
            reps: 1,
            full_evals: 3,
            ft_frac: 0.34,
            searchers: vec![SearcherKind::Random],
            datasets: vec!["D2".into()],
            threads: 1,
            ..Default::default()
        }
    }

    #[test]
    fn metrics_formulas() {
        let r = RunRecord {
            dataset: "D1".into(),
            strategy: "gendst".into(),
            searcher: "smbo",
            rep: 0,
            time_full_s: 10.0,
            time_sub_s: 2.0,
            acc_full: 0.9,
            acc_sub: 0.88,
            final_desc: String::new(),
        };
        assert!((r.time_reduction() - 0.8).abs() < 1e-12);
        assert!((r.relative_accuracy() - 0.88 / 0.9).abs() < 1e-12);
    }

    #[test]
    fn end_to_end_cell_runs() {
        let cfg = tiny_cfg();
        let prep = prepare("D2", &cfg, 0);
        let full = run_full(&prep, SearcherKind::Random, &cfg, 0);
        assert!(full.test_acc > 0.0 && full.elapsed_s > 0.0);
        let rec = run_strategy(
            &prep,
            "D2",
            "gendst",
            SearcherKind::Random,
            &full,
            &cfg,
            0,
            None,
        );
        assert!(rec.time_sub_s > 0.0);
        assert!(rec.acc_sub > 0.0);
        // at this smoke scale (3 evals, tiny rows) the subset flow is
        // not guaranteed to beat the — now refit-free, hence smaller —
        // full window on a loaded runner; the actual speedup claim is
        // asserted at realistic scale in
        // tests/integration.rs::substrat_flow_beats_full_automl_on_time
        assert!(rec.time_reduction().is_finite(), "bad metric: {rec:?}");
    }

    #[test]
    fn nf_cell_runs_without_fine_tune() {
        let cfg = tiny_cfg();
        let prep = prepare("D2", &cfg, 0);
        let full = run_full(&prep, SearcherKind::Random, &cfg, 0);
        let rec = run_strategy(
            &prep,
            "D2",
            "substrat-nf",
            SearcherKind::Random,
            &full,
            &cfg,
            0,
            None,
        );
        assert_eq!(rec.strategy, "substrat-nf");
    }

    #[test]
    fn thread_knob_does_not_change_the_winner() {
        // random-search proposals and per-(config, fold) fit RNGs are
        // independent of batching, so the wired engine is pure speed
        let base = tiny_cfg();
        let prep = prepare("D2", &base, 0);
        let mut wide = tiny_cfg();
        wide.threads = 4;
        let a = run_full(&prep, SearcherKind::Random, &base, 0);
        let b = run_full(&prep, SearcherKind::Random, &wide, 0);
        assert_eq!(a.best_desc, b.best_desc);
        assert_eq!(a.test_acc, b.test_acc);
    }

    #[test]
    fn mc24h_setup_is_subtracted_exactly_once() {
        // the MC-24H budget probe reports a positive setup window; the
        // raw SubStrat total contains it, and charged_time_s removes it
        // exactly once — record time = raw − setup (never raw − 2·setup)
        let cfg = ExpConfig {
            min_rows: 400,
            max_rows: 700,
            ..tiny_cfg()
        };
        let prep = prepare("D2", &cfg, 0);
        let run = strategy_search(
            &prep,
            "mc-24h",
            SearcherKind::Random,
            &cfg,
            0,
            None,
            cfg.ft_frac,
            1,
        );
        let setup = run.outcome.setup_s;
        assert!(setup > 0.0, "mc-24h must report a probe window");
        let charged = charged_time_s(run.total_time_s, &run.outcome, TimingMode::Wall);
        assert!(
            (run.total_time_s - charged - setup).abs() < 1e-9,
            "subtracted {} instead of the setup {setup}",
            run.total_time_s - charged
        );
        // the CPU-proxy clock subtracts its own measurement, not wall
        let cpu_charged = charged_time_s(1.0, &run.outcome, TimingMode::CpuProxy);
        assert!((1.0 - cpu_charged - run.outcome.setup_cpu_s.min(1.0)).abs() < 1e-9);
        // idempotence guard: charging an already-charged window again
        // would shrink it further — exactly the double subtraction the
        // seed performed
        let double = charged_time_s(charged, &run.outcome, TimingMode::Wall);
        assert!(double <= charged);
    }

    #[test]
    fn prepare_resolves_csv_specs_with_row_cap() {
        let dir = std::env::temp_dir().join("substrat_prepare_csv");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("cells.csv");
        let mut text = String::from("x,z,label\n");
        for i in 0..120 {
            text.push_str(&format!(
                "{},{},{}\n",
                i as f64 / 7.0,
                ["u", "v"][i % 2],
                ["p", "q"][(i / 3) % 2]
            ));
        }
        std::fs::write(&path, text).unwrap();
        let cfg = ExpConfig {
            max_rows: 60,
            ..tiny_cfg()
        };
        let prep = prepare(path.to_str().unwrap(), &cfg, 0);
        // 120 file rows, capped to 60, then 25% held out
        assert_eq!(prep.train.n_rows + prep.test.n_rows, 60);
        assert_eq!(prep.train.n_cols(), 3);
        assert_eq!(prep.codes.n_rows, prep.train.n_rows);
        // deterministic per (seed, rep)
        let again = prepare(path.to_str().unwrap(), &cfg, 0);
        assert_eq!(prep.train.columns[0].values, again.train.columns[0].values);
        // an uncapped prepare keeps every file row
        let roomy = ExpConfig {
            max_rows: 100_000,
            ..tiny_cfg()
        };
        let all = prepare(path.to_str().unwrap(), &roomy, 0);
        assert_eq!(all.train.n_rows + all.test.n_rows, 120);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn table4_names_match_paper() {
        let names = table4_strategy_names();
        assert_eq!(names.len(), 8, "paper Table 4 has 8 rows: {names:?}");
        assert!(names.contains(&"gendst") && names.contains(&"substrat-nf"));
    }

    #[test]
    fn paper_labels_cover_all() {
        for n in table4_strategy_names() {
            assert_ne!(paper_label(n), "?", "{n}");
        }
    }
}
