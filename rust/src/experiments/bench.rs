//! The benchmark-trajectory subsystem (DESIGN.md §5.4): one `bench`
//! entry point that expands every perf target — the five paper-artifact
//! sweeps and the five engine micro-benchmarks — into named *suites*
//! and emits one machine-readable `BENCH_<n>.json` per run, so "the
//! engine got faster" is a diff between two files instead of a claim.
//!
//! * **Cell suites** (`table4`, `fig2`, `fig3`, `fig4`, `fig5`) expand
//!   through the same `cells()` functions the experiment drivers use
//!   and run through the contention-free cell runner (§5.2) with the
//!   journal forced off — a bench must re-measure, never resume.
//! * **Micro suites** (`gendst`, `automl`, `entropy`, `runtime`,
//!   `pareto`) drive
//!   `util::bench::Bench` (honors `BENCH_QUICK=1`) and keep the old
//!   bench binaries' equivalence assertions: identical winners across
//!   engines is checked before any number is trusted.
//! * Every record is a flat single-line JSON object (`util::json`), so
//!   the file round-trips bit-exactly; the writer validates each record
//!   against [`validate_record`] before emitting it.
//! * `--dry-run` exercises the full expansion + fingerprinting +
//!   serialization + validation path with zero-cost stub measurements —
//!   the harness stays integration-testable on machines where real
//!   timings would be noise.
//!
//! File numbering: `BENCH_<n>.json` with `n = max(existing) + 1`,
//! opened `create_new` — monotone, never clobbers.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::automl::eval::EvalPolicy;
use crate::automl::{run_automl, AutoMlConfig, SearcherKind};
use crate::data::registry::{self, DataSource};
use crate::data::{CodeMatrix, Matrix};
use crate::experiments::runner::{config_fingerprint, Cell, Runner};
use crate::experiments::{fig2, fig3, fig4, fig5, table4, ExpConfig, RunRecord, TimingMode};
use crate::gendst::fitness::FitnessBackend;
use crate::gendst::pareto::{self, Objective};
use crate::gendst::{default_dst_size, gen_dst, GenDstConfig};
use crate::measures::entropy::{
    column_hist, entropy_of_counts, full_entropy, hist_swap_row, subset_entropy, EntropyMeasure,
};
use crate::runtime::models_exec::{
    class_mask, pack_batch, pack_epoch, LogregParams, MlpParams, ModelsExec,
};
use crate::runtime::shapes::{BATCH, EPOCH_TILES};
use crate::runtime::{self, entropy_exec::EntropyExec};
use crate::util::bench::{black_box, Bench, BenchResult};
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::{unix_time_s, CpuTimer, Stopwatch};

/// Schema tag stamped into every header record. Versioning rule:
/// *adding* a field is backward-compatible and keeps the tag (readers
/// must ignore unknown fields); removing, renaming, or changing the
/// meaning of a required field bumps it to `bench-v2`.
pub const SCHEMA: &str = "bench-v1";

/// What drives a suite's measurements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SuiteKind {
    /// expands to experiment [`Cell`]s through the §5.2 runner
    Cells,
    /// drives `util::bench::Bench` micro-benchmarks
    Micro,
}

/// One named suite in the registry.
#[derive(Debug)]
pub struct SuiteDef {
    pub name: &'static str,
    pub kind: SuiteKind,
    /// the `benches/bench_*.rs` target this suite subsumes
    pub replaces: &'static str,
    pub what: &'static str,
}

/// The suite registry — one entry per historical bench binary, in a
/// fixed order (record order inside a BENCH file follows it).
pub fn suite_defs() -> &'static [SuiteDef] {
    const DEFS: &[SuiteDef] = &[
        SuiteDef {
            name: "table4",
            kind: SuiteKind::Cells,
            replaces: "bench_table4",
            what: "Table-4 strategy grid through the cell runner",
        },
        SuiteDef {
            name: "fig2",
            kind: SuiteKind::Cells,
            replaces: "bench_fig2_per_dataset",
            what: "per-dataset points (SMBO-pinned strategy grid)",
        },
        SuiteDef {
            name: "fig3",
            kind: SuiteKind::Cells,
            replaces: "bench_fig3_skyline",
            what: "configuration-skyline variant grid",
        },
        SuiteDef {
            name: "fig4",
            kind: SuiteKind::Cells,
            replaces: "bench_fig4_heatmap",
            what: "(n, m) DST-size heatmap grid",
        },
        SuiteDef {
            name: "fig5",
            kind: SuiteKind::Cells,
            replaces: "bench_fig5_isolated",
            what: "isolated n / m axis sweeps",
        },
        SuiteDef {
            name: "gendst",
            kind: SuiteKind::Micro,
            replaces: "bench_gendst",
            what: "GA engine: naive vs incremental, islands vs single",
        },
        SuiteDef {
            name: "automl",
            kind: SuiteKind::Micro,
            replaces: "bench_automl",
            what: "eval engine: serial-naive vs parallel-memoized",
        },
        SuiteDef {
            name: "entropy",
            kind: SuiteKind::Micro,
            replaces: "bench_entropy",
            what: "fitness hot path: native vs PJRT entropy kernels",
        },
        SuiteDef {
            name: "runtime",
            kind: SuiteKind::Micro,
            replaces: "bench_runtime",
            what: "PJRT call overhead: step vs epoch, predict",
        },
        SuiteDef {
            name: "pareto",
            kind: SuiteKind::Micro,
            replaces: "bench_pareto",
            what: "NSGA-II machinery: sort/crowding scaling, MO vs scalar engine",
        },
    ];
    DEFS
}

fn suite_def(name: &str) -> &'static SuiteDef {
    suite_defs()
        .iter()
        .find(|d| d.name == name)
        .unwrap_or_else(|| panic!("unknown bench suite {name:?}"))
}

/// Resolve a CLI suite spec — `all`, `cells`, `micro`, or a comma list
/// of suite names — into registry-ordered names. Panics (with the known
/// names) on anything unknown, so typos fail before any work starts.
pub fn resolve_suite_names(spec: &str) -> Vec<&'static str> {
    let all = suite_defs();
    let of_kind =
        |k: SuiteKind| all.iter().filter(|d| d.kind == k).map(|d| d.name).collect::<Vec<_>>();
    match spec {
        "all" => all.iter().map(|d| d.name).collect(),
        "cells" => of_kind(SuiteKind::Cells),
        "micro" => of_kind(SuiteKind::Micro),
        list => list
            .split(',')
            .map(|raw| {
                let name = raw.trim();
                all.iter().find(|d| d.name == name).map(|d| d.name).unwrap_or_else(|| {
                    let known: Vec<&str> = all.iter().map(|d| d.name).collect();
                    panic!("unknown bench suite {name:?} (want all|cells|micro or {known:?})")
                })
            })
            .collect(),
    }
}

/// The quick sweep shape the old per-figure bench binaries hard-coded:
/// one cheap rep over two mid-size datasets, SMBO only, full hardware
/// budget, journal off. `bench` starts from this; `--full` starts from
/// `ExpConfig::default()` instead.
pub fn quick_exp_config() -> ExpConfig {
    ExpConfig {
        scale: 0.05,
        min_rows: 2_000,
        max_rows: 4_000,
        reps: 1,
        full_evals: 6,
        searchers: vec![SearcherKind::Smbo],
        datasets: vec!["D2".into(), "D3".into()],
        threads: 0,
        journal: false,
        out_dir: PathBuf::from("results"),
        ..Default::default()
    }
}

/// One bench invocation: which suites, real or dry, and the experiment
/// shape cell suites expand against (`exp.out_dir` receives the
/// `BENCH_<n>.json`; `exp.journal` is forced off).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub suites: Vec<String>,
    pub dry_run: bool,
    pub exp: ExpConfig,
}

/// Where one bench run landed.
#[derive(Debug, Clone)]
pub struct BenchRun {
    pub path: PathBuf,
    pub run_no: u64,
    pub records: usize,
}

/// One flat bench record before serialization.
pub type Record = Vec<(String, Json)>;

fn str_field(k: &str, v: &str) -> (String, Json) {
    (k.to_string(), Json::Str(v.to_string()))
}

fn num_field(k: &str, v: f64) -> (String, Json) {
    (k.to_string(), Json::Num(v))
}

fn bool_field(k: &str, v: bool) -> (String, Json) {
    (k.to_string(), Json::Bool(v))
}

fn hostname() -> String {
    std::env::var("HOSTNAME")
        .ok()
        .or_else(|| {
            std::fs::read_to_string("/proc/sys/kernel/hostname")
                .ok()
                .map(|s| s.trim().to_string())
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn header_record(defs: &[&SuiteDef], dry: bool, exp: &ExpConfig) -> Record {
    let suites: Vec<&str> = defs.iter().map(|d| d.name).collect();
    vec![
        str_field("record", "header"),
        str_field("schema", SCHEMA),
        str_field("suites", &suites.join(",")),
        str_field("timing", exp.timing.name()),
        num_field("threads", pool::resolve_threads(exp.threads) as f64),
        str_field("host", &hostname()),
        str_field("os", std::env::consts::OS),
        str_field("arch", std::env::consts::ARCH),
        str_field("toolchain", option_env!("RUSTUP_TOOLCHAIN").unwrap_or("unknown")),
        str_field("crate_version", env!("CARGO_PKG_VERSION")),
        num_field("unix_time", unix_time_s()),
        bool_field("dry", dry),
    ]
}

pub(crate) fn suite_record(
    suite: &str,
    cells: usize,
    wall_s: f64,
    cpu_s: f64,
    dry: bool,
) -> Record {
    vec![
        str_field("record", "suite"),
        str_field("suite", suite),
        num_field("cells", cells as f64),
        num_field("wall_s", wall_s),
        num_field("cpu_s", cpu_s),
        bool_field("dry", dry),
    ]
}

pub(crate) fn cell_record(
    suite: &str,
    cell: &Cell,
    cell_fp: &str,
    src_fp: &str,
    cfg_fp: &str,
    timing: TimingMode,
    rec: Option<&RunRecord>,
) -> Record {
    let (tf, ts, af, asub) = match rec {
        Some(r) => (r.time_full_s, r.time_sub_s, r.acc_full, r.acc_sub),
        None => (0.0, 0.0, 0.0, 0.0),
    };
    vec![
        str_field("record", "cell"),
        str_field("suite", suite),
        str_field("dataset", &cell.symbol),
        str_field("strategy", &cell.strategy),
        str_field("label", cell.label()),
        str_field("searcher", cell.searcher.name()),
        num_field("rep", cell.rep as f64),
        str_field("dst", &cell.dst.tag()),
        str_field("cell", cell_fp),
        str_field("src", src_fp),
        str_field("cfg", cfg_fp),
        str_field("timing", timing.name()),
        num_field("time_full_s", tf),
        num_field("time_sub_s", ts),
        num_field("acc_full", af),
        num_field("acc_sub", asub),
        bool_field("dry", rec.is_none()),
    ]
}

fn micro_record(suite: &str, r: &BenchResult, dry: bool) -> Record {
    let mut rec = vec![
        str_field("record", "micro"),
        str_field("suite", suite),
        str_field("name", &r.name),
        num_field("iters", r.iters as f64),
        num_field("mean_ns", r.mean_ns),
        num_field("std_ns", r.std_ns),
    ];
    if let Some(t) = r.throughput {
        rec.push(num_field("throughput", t));
    }
    rec.push(bool_field("dry", dry));
    rec
}

fn stub_micro(suite: &str, name: &str) -> Record {
    micro_record(
        suite,
        &BenchResult {
            name: name.to_string(),
            iters: 0,
            mean_ns: 0.0,
            std_ns: 0.0,
            throughput: None,
        },
        true,
    )
}

fn counter_record(suite: &str, name: &str, value: f64, dry: bool) -> Record {
    vec![
        str_field("record", "counter"),
        str_field("suite", suite),
        str_field("name", name),
        num_field("value", value),
        bool_field("dry", dry),
    ]
}

/// Validate one record against the documented schema. Required fields
/// must be present with the right type; *unknown* fields are allowed —
/// that is the additive half of the versioning rule. The writer calls
/// this on every record before emitting, so a BENCH file can never
/// contain a record this check would reject.
pub fn validate_record(rec: &[(String, Json)]) -> Result<(), String> {
    let str_of = |k: &str| -> Result<&str, String> {
        json::get(rec, k)
            .and_then(Json::as_str)
            .ok_or_else(|| format!("missing/mistyped string field {k:?}"))
    };
    let num_of = |k: &str| -> Result<f64, String> {
        let v = json::get(rec, k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing/mistyped number field {k:?}"))?;
        if !v.is_finite() {
            return Err(format!("non-finite number field {k:?}"));
        }
        Ok(v)
    };
    let nonneg = |k: &str| -> Result<f64, String> {
        let v = num_of(k)?;
        if v < 0.0 {
            return Err(format!("negative field {k:?}: {v}"));
        }
        Ok(v)
    };
    let bool_of = |k: &str| -> Result<(), String> {
        match json::get(rec, k) {
            Some(Json::Bool(_)) => Ok(()),
            _ => Err(format!("missing/mistyped bool field {k:?}")),
        }
    };
    match str_of("record")? {
        "header" => {
            let schema = str_of("schema")?;
            if schema != SCHEMA {
                return Err(format!("schema {schema:?}, validator knows {SCHEMA:?} only"));
            }
            for k in ["suites", "timing", "host", "os", "arch", "toolchain", "crate_version"] {
                str_of(k)?;
            }
            nonneg("threads")?;
            nonneg("unix_time")?;
            bool_of("dry")?;
        }
        "suite" => {
            str_of("suite")?;
            nonneg("cells")?;
            nonneg("wall_s")?;
            nonneg("cpu_s")?;
            bool_of("dry")?;
        }
        "cell" => {
            let keys = [
                "suite", "dataset", "strategy", "label", "searcher", "dst", "cell", "src",
                "cfg", "timing",
            ];
            for k in keys {
                str_of(k)?;
            }
            let rep = nonneg("rep")?;
            if rep.fract() != 0.0 {
                return Err(format!("rep must be an integer, got {rep}"));
            }
            for k in ["time_full_s", "time_sub_s", "acc_full", "acc_sub"] {
                nonneg(k)?;
            }
            bool_of("dry")?;
        }
        "micro" => {
            str_of("suite")?;
            str_of("name")?;
            nonneg("iters")?;
            nonneg("mean_ns")?;
            nonneg("std_ns")?;
            if json::get(rec, "throughput").is_some() {
                nonneg("throughput")?;
            }
            bool_of("dry")?;
        }
        "counter" => {
            str_of("suite")?;
            str_of("name")?;
            num_of("value")?;
            bool_of("dry")?;
        }
        other => return Err(format!("unknown record kind {other:?}")),
    }
    Ok(())
}

/// `BENCH_<n>.json` for run number `n`.
pub fn bench_file_name(n: u64) -> String {
    format!("BENCH_{n}.json")
}

/// Parse a run number back out of a `BENCH_<n>.json` file name.
pub fn parse_bench_file_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("BENCH_")?.strip_suffix(".json")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// The next run number for `dir`: `max(existing) + 1`, starting at 1.
/// Non-matching file names are ignored, never renumbered.
pub fn next_run_number(dir: &Path) -> u64 {
    let mut max = 0u64;
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            if let Some(n) = entry.file_name().to_str().and_then(parse_bench_file_name) {
                max = max.max(n);
            }
        }
    }
    max + 1
}

/// Claim the next `BENCH_<n>.json` with `create_new` semantics: if a
/// concurrent run (or a stale scan) already owns the number, bump and
/// retry — numbering is monotone and an existing file is never
/// truncated or overwritten.
fn allocate_bench_file(dir: &Path) -> (std::fs::File, PathBuf, u64) {
    let mut n = next_run_number(dir);
    loop {
        let path = dir.join(bench_file_name(n));
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(file) => return (file, path, n),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => n += 1,
            Err(e) => panic!("cannot create {}: {e}", path.display()),
        }
    }
}

fn suite_cells(name: &str, cfg: &ExpConfig) -> Vec<Cell> {
    match name {
        "table4" => table4::cells(cfg),
        "fig2" => fig2::cells(cfg),
        "fig3" => fig3::cells(cfg),
        "fig4" => fig4::cells(cfg),
        "fig5" => fig5::cells(cfg),
        other => panic!("not a cell suite: {other:?}"),
    }
}

fn cell_suite_records(name: &str, exp: &ExpConfig, dry: bool, out: &mut Vec<Record>) {
    let cells = suite_cells(name, exp);
    let cfg_fp = config_fingerprint(exp);
    let mut source_fps: HashMap<String, String> = HashMap::new();
    for c in &cells {
        if !source_fps.contains_key(c.symbol.as_str()) {
            source_fps.insert(c.symbol.clone(), DataSource::parse(&c.symbol).fingerprint());
        }
    }
    if dry {
        // full expansion + fingerprinting, zero-cost stub measurements
        for c in &cells {
            let src = &source_fps[c.symbol.as_str()];
            let fp = c.fingerprint(exp, &cfg_fp, src);
            out.push(cell_record(name, c, &fp, src, &cfg_fp, exp.timing, None));
        }
        out.push(suite_record(name, cells.len(), 0.0, 0.0, true));
        return;
    }
    // suite-level wall AND CPU totals bracket the runner, whatever
    // `exp.timing` the per-cell windows use — the TimingMode split at
    // suite granularity
    let sw = Stopwatch::start();
    let cpu = CpuTimer::start();
    let outcomes = Runner::new(exp).run(&cells);
    let (wall_s, cpu_s) = (sw.elapsed_s(), cpu.elapsed_s());
    for o in &outcomes {
        let src = &source_fps[o.cell.symbol.as_str()];
        let fp = o.cell.fingerprint(exp, &cfg_fp, src);
        out.push(cell_record(name, &o.cell, &fp, src, &cfg_fp, exp.timing, Some(&o.record)));
    }
    out.push(suite_record(name, outcomes.len(), wall_s, cpu_s, false));
    println!(
        "bench suite {name}: {} cell(s), wall {wall_s:.2}s, cpu {cpu_s:.2}s",
        outcomes.len()
    );
}

/// The (rows, cols) a registry symbol generates at `scale` — computed
/// from the spec so dry runs name the same shapes real runs measure,
/// without generating any data.
fn registry_shape(symbol: &str, scale: f64) -> (usize, usize) {
    let spec = registry::spec_for(symbol, scale, 7);
    (spec.n_rows, spec.n_cols())
}

fn micro_suite_records(name: &str, dry: bool) -> Vec<Record> {
    match name {
        "gendst" => suite_gendst(dry),
        "automl" => suite_automl(dry),
        "entropy" => suite_entropy(dry),
        "runtime" => suite_runtime(dry),
        "pareto" => suite_pareto(dry),
        other => panic!("not a micro suite: {other:?}"),
    }
}

/// GA-engine suite (subsumes `bench_gendst`): naive vs incremental
/// backend per dataset scale, memo-hit-rate counters, islands-vs-single
/// timing with the single-island equivalence assertion kept.
fn suite_gendst(dry: bool) -> Vec<Record> {
    const SUITE: &str = "gendst";
    let mut out = Vec::new();
    let mut b = Bench::new();
    for (symbol, scale) in [("D2", 0.4), ("D2", 1.0), ("D3", 1.0), ("D1", 0.1)] {
        let (rows, cols) = registry_shape(symbol, scale);
        let (n, m) = default_dst_size(rows, cols);
        let shape = format!("{symbol} {rows}x{cols} -> ({n},{m})");
        if dry {
            for tag in ["naive      ", "incremental"] {
                out.push(stub_micro(SUITE, &format!("gen_dst {tag} {shape}")));
            }
            out.push(counter_record(SUITE, &format!("memo_hit_rate {shape}"), 0.0, true));
            continue;
        }
        let f = registry::load(symbol, scale, 7);
        let codes = CodeMatrix::from_frame(&f);
        for (tag, backend) in [
            ("naive      ", FitnessBackend::NaiveNative),
            ("incremental", FitnessBackend::Incremental),
        ] {
            let cfg = GenDstConfig { backend, seed: 1, ..Default::default() };
            let r = b
                .bench(&format!("gen_dst {tag} {shape}"), || {
                    black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
                })
                .clone();
            out.push(micro_record(SUITE, &r, false));
        }
        let cfg = GenDstConfig { seed: 1, ..Default::default() };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
        let rate = res.memo_hits as f64 / (res.memo_hits + res.fitness_evals).max(1) as f64;
        out.push(counter_record(SUITE, &format!("memo_hit_rate {shape}"), rate, false));
    }

    // islands vs single population (same total φ, same seed): the
    // island engine's win is wall clock — the generation loop itself
    // fans out — while islands=1 must reproduce the single-population
    // reference winner (PR 5 acceptance criterion, kept live here)
    let (rows, cols) = registry_shape("D3", 1.0);
    let (n, m) = default_dst_size(rows, cols);
    let shape = format!("D3 {rows}x{cols} -> ({n},{m})");
    if dry {
        for islands in [1usize, 4] {
            out.push(stub_micro(SUITE, &format!("gen_dst islands={islands}   {shape}")));
        }
        out.push(counter_record(SUITE, &format!("islands_speedup {shape}"), 0.0, true));
        return out;
    }
    let f = registry::load("D3", 1.0, 7);
    let codes = CodeMatrix::from_frame(&f);
    let mut means = Vec::new();
    for islands in [1usize, 4] {
        let cfg = GenDstConfig { islands, seed: 1, ..Default::default() };
        let r = b
            .bench(&format!("gen_dst islands={islands}   {shape}"), || {
                black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
            })
            .clone();
        means.push(r.mean_ns);
        out.push(micro_record(SUITE, &r, false));
    }
    let speedup = means[0] / means[1].max(1e-9);
    out.push(counter_record(SUITE, &format!("islands_speedup {shape}"), speedup, false));
    let reference = gen_dst(
        &f,
        &codes,
        &EntropyMeasure,
        n,
        m,
        &GenDstConfig {
            backend: FitnessBackend::NaiveNative,
            islands: 1,
            seed: 1,
            ..Default::default()
        },
    );
    let single = gen_dst(
        &f,
        &codes,
        &EntropyMeasure,
        n,
        m,
        &GenDstConfig { islands: 1, seed: 1, ..Default::default() },
    );
    assert_eq!(
        single.dst, reference.dst,
        "islands=1 must reproduce the single-population reference winner"
    );
    assert!((single.loss - reference.loss).abs() <= 1e-9);
    out
}

fn serial_naive() -> EvalPolicy {
    EvalPolicy {
        threads: 1,
        memoize: false,
        early_termination: false,
    }
}

fn automl_cfg(
    searcher: SearcherKind,
    evals: usize,
    batch: usize,
    policy: EvalPolicy,
) -> AutoMlConfig {
    let mut cfg = AutoMlConfig::new(searcher, evals, 11);
    cfg.batch_size = batch;
    cfg.policy = policy;
    cfg
}

/// Eval-engine suite (subsumes `bench_automl`): serial-naive vs the
/// parallel + memoized engine on identical seeds and batch sizes — the
/// two are bit-compatible, so the delta is pure engine speed. The
/// determinism preamble and same-batch equivalence assertions from the
/// old binary run before anything is timed.
fn suite_automl(dry: bool) -> Vec<Record> {
    const SUITE: &str = "automl";
    let mut out = Vec::new();
    if !dry {
        let f = registry::load("D2", 0.05, 3);
        let reference = run_automl(&f, &automl_cfg(SearcherKind::Random, 8, 4, serial_naive()));
        for threads in [2usize, 4, 8] {
            let p = EvalPolicy { threads, ..Default::default() };
            let r = run_automl(&f, &automl_cfg(SearcherKind::Random, 8, 4, p));
            assert_eq!(r.best, reference.best, "thread count changed the winner");
            assert_eq!(r.best_cv.to_bits(), reference.best_cv.to_bits());
        }
    }
    let mut b = Bench::new();
    for (symbol, scale, evals) in [("D2", 0.08, 10usize), ("D3", 0.12, 10)] {
        let (rows, cols) = registry_shape(symbol, scale);
        let shape = format!("{symbol} {rows}x{cols}");
        for searcher in [SearcherKind::Smbo, SearcherKind::Gp] {
            let variants = [
                ("serial-naive b=1", 1usize, serial_naive()),
                ("serial-naive b=4", 4, serial_naive()),
                ("par-memoized b=4", 4, EvalPolicy::default()),
            ];
            if dry {
                for (tag, _, _) in variants {
                    let name = format!("automl {} {tag} {shape}", searcher.name());
                    out.push(stub_micro(SUITE, &name));
                }
                let counter = format!("memo_hit_rate {shape} {}", searcher.name());
                out.push(counter_record(SUITE, &counter, 0.0, true));
                continue;
            }
            let f = registry::load(symbol, scale, 7);
            for (tag, batch, policy) in variants {
                let cfg = automl_cfg(searcher, evals, batch, policy);
                let name = format!("automl {} {tag} {shape}", searcher.name());
                let r = b
                    .bench(&name, || {
                        black_box(run_automl(&f, &cfg));
                    })
                    .clone();
                out.push(micro_record(SUITE, &r, false));
            }
            // same-batch equivalence: the engine must not change the
            // outcome, only the wall clock
            let slow = run_automl(&f, &automl_cfg(searcher, evals, 4, serial_naive()));
            let fast = run_automl(&f, &automl_cfg(searcher, evals, 4, EvalPolicy::default()));
            assert_eq!(slow.best, fast.best, "{shape}: engine changed the winner");
            let rate = fast.memo_hits as f64 / fast.evals.max(1) as f64;
            let counter = format!("memo_hit_rate {shape} {}", searcher.name());
            out.push(counter_record(SUITE, &counter, rate, false));
        }
    }
    out
}

/// Entropy hot-path suite (subsumes `bench_entropy`): native
/// stack-histogram entropy vs the AOT Pallas kernel on PJRT (single and
/// batch-16), the full-table scan, and the incremental-engine
/// primitives (O(1) hist delta vs O(n) column rebuild).
fn suite_entropy(dry: bool) -> Vec<Record> {
    const SUITE: &str = "entropy";
    let mut out = Vec::new();
    let pairs = [(114usize, 6usize), (1000, 8), (1000, 31)];
    if dry {
        for (n, m) in pairs {
            out.push(stub_micro(SUITE, &format!("native subset_entropy {n}x{m}")));
            out.push(stub_micro(SUITE, &format!("pjrt   subset_entropy {n}x{m}")));
            out.push(stub_micro(SUITE, &format!("pjrt   batch16 entropy {n}x{m}")));
        }
        out.push(stub_micro(SUITE, "native full_entropy 13k x 23"));
        for n in [114usize, 1000] {
            out.push(stub_micro(SUITE, &format!("rebuild column_hist n={n}")));
            out.push(stub_micro(SUITE, &format!("delta hist_swap_row n={n}")));
        }
        return out;
    }
    let f = registry::load("D1", 0.1, 1); // 12,988 x 23
    let codes = CodeMatrix::from_frame(&f);
    let mut rng = Rng::new(42);
    let mut b = Bench::new();
    for (n, m) in pairs {
        let rows = rng.sample_distinct(f.n_rows, n.min(f.n_rows));
        let mut cols = rng.sample_distinct(f.n_cols(), m.min(f.n_cols()));
        if !cols.contains(&(f.target as u32)) {
            cols[0] = f.target as u32;
        }
        let r = b
            .bench_throughput(&format!("native subset_entropy {n}x{m}"), n * m, || {
                black_box(subset_entropy(&codes, &rows, &cols));
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
        let rt = runtime::thread_current().unwrap();
        let mut exec = EntropyExec::new(&rt);
        let r = b
            .bench_throughput(&format!("pjrt   subset_entropy {n}x{m}"), n * m, || {
                black_box(exec.subset_entropy(&codes, &rows, &cols).unwrap());
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
        let subsets: Vec<(&[u32], &[u32])> =
            (0..16).map(|_| (rows.as_slice(), cols.as_slice())).collect();
        let r = b
            .bench_throughput(&format!("pjrt   batch16 entropy {n}x{m}"), 16 * n * m, || {
                black_box(exec.batch_entropy(&codes, &subsets).unwrap());
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
    }
    let r = b
        .bench("native full_entropy 13k x 23", || {
            black_box(full_entropy(&codes));
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    for n in [114usize, 1000] {
        let rows = rng.sample_distinct(f.n_rows, n);
        let col0 = codes.column(0);
        let mut hist = column_hist(&codes, 0, &rows);
        let (old, new) = (rows[0], {
            let mut v = 0u32;
            while rows.contains(&v) {
                v += 1;
            }
            v
        });
        let r = b
            .bench_throughput(&format!("rebuild column_hist n={n}"), n, || {
                black_box(column_hist(&codes, 0, &rows));
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
        let r = b
            .bench_throughput(&format!("delta hist_swap_row n={n}"), n, || {
                hist_swap_row(&mut hist, col0, old, new);
                hist_swap_row(&mut hist, col0, new, old); // restore
                black_box(entropy_of_counts(&hist, n));
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
    }
    out
}

/// PJRT call-overhead suite (subsumes `bench_runtime`): entropy-free
/// model kernels — train-step vs train-epoch (the §Perf L2
/// optimization) and prediction.
fn suite_runtime(dry: bool) -> Vec<Record> {
    const SUITE: &str = "runtime";
    let names = [
        "logreg_train_step (256 rows/call)",
        "logreg_train_epoch (4096 rows/call)",
        "mlp_train_step (256 rows/call)",
        "mlp_train_epoch (4096 rows/call)",
        "logreg_predict (256 rows/call)",
    ];
    if dry {
        return names.iter().map(|n| stub_micro(SUITE, n)).collect();
    }
    let mut out = Vec::new();
    let rt = runtime::thread_current().expect("run `make artifacts`");
    let exec = ModelsExec::new(&rt);
    let mut rng = Rng::new(3);
    let mut b = Bench::new();

    let rows = EPOCH_TILES * BATCH;
    let mut x = Matrix::zeros(rows, 32);
    let mut y = vec![0u32; rows];
    for i in 0..rows {
        y[i] = (i % 2) as u32;
        for j in 0..32 {
            x.set(i, j, rng.normal() as f32);
        }
    }
    let cmask = class_mask(2);
    let idx_small: Vec<usize> = (0..BATCH).collect();
    let idx_epoch: Vec<usize> = (0..rows).collect();
    let batch = pack_batch(&x, &y, &idx_small).unwrap();
    let epoch = pack_epoch(&x, &y, &idx_epoch).unwrap();

    let mut lp = LogregParams::zeros();
    let r = b
        .bench_throughput(names[0], BATCH, || {
            black_box(exec.logreg_step(&mut lp, &batch, &cmask, 0.1, 0.0).unwrap());
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    let r = b
        .bench_throughput(names[1], rows, || {
            black_box(exec.logreg_epoch(&mut lp, &epoch, &cmask, 0.1, 0.0).unwrap());
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    let mut mp = MlpParams::init(&mut Rng::new(4));
    let r = b
        .bench_throughput(names[2], BATCH, || {
            black_box(exec.mlp_step(&mut mp, &batch, &cmask, 0.1, 0.0).unwrap());
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    let r = b
        .bench_throughput(names[3], rows, || {
            black_box(exec.mlp_epoch(&mut mp, &epoch, &cmask, 0.1, 0.0).unwrap());
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    let r = b
        .bench_throughput(names[4], BATCH, || {
            black_box(exec.logreg_predict(&lp, &batch.x, &cmask).unwrap());
        })
        .clone();
    out.push(micro_record(SUITE, &r, false));
    out
}

/// NSGA-II machinery suite (subsumes `bench_pareto`): non-dominated
/// sort + crowding scaling on synthetic 3-objective clouds, then the
/// multi-objective engine head-to-head against the scalar engine on
/// the same input — the per-generation overhead the MO path pays for
/// returning a whole front from one run (DESIGN.md §10). The front-size
/// counter records how many operating points that one run served.
fn suite_pareto(dry: bool) -> Vec<Record> {
    const SUITE: &str = "pareto";
    let mut out = Vec::new();
    let mut b = Bench::new();
    for n in [64usize, 256, 1024] {
        let name = format!("rank_and_crowding {n}x3");
        if dry {
            out.push(stub_micro(SUITE, &name));
            continue;
        }
        let mut rng = Rng::new(5);
        let objs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..3).map(|_| rng.f64()).collect()).collect();
        let r = b
            .bench(&name, || {
                black_box(pareto::rank_and_crowding(&objs));
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
    }

    let (rows, cols) = registry_shape("D2", 0.4);
    let (n, m) = default_dst_size(rows, cols);
    let shape = format!("D2 {rows}x{cols} -> ({n},{m})");
    if dry {
        for tag in ["scalar ", "nsga-ii"] {
            out.push(stub_micro(SUITE, &format!("gen_dst {tag} {shape}")));
        }
        out.push(counter_record(SUITE, &format!("front_size {shape}"), 0.0, true));
        return out;
    }
    let f = registry::load("D2", 0.4, 7);
    let codes = CodeMatrix::from_frame(&f);
    let mo = vec![Objective::Fidelity, Objective::SubsetSize, Objective::DownstreamTime];
    for (tag, objectives) in
        [("scalar ", vec![Objective::Fidelity]), ("nsga-ii", mo.clone())]
    {
        let cfg = GenDstConfig { objectives, seed: 1, ..Default::default() };
        let r = b
            .bench(&format!("gen_dst {tag} {shape}"), || {
                black_box(gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg));
            })
            .clone();
        out.push(micro_record(SUITE, &r, false));
    }
    let cfg = GenDstConfig { objectives: mo, seed: 1, ..Default::default() };
    let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &cfg);
    out.push(counter_record(
        SUITE,
        &format!("front_size {shape}"),
        res.front.len() as f64,
        false,
    ));
    out
}

/// Run the configured suites and write one `BENCH_<n>.json`. Records
/// are collected (and validated) first, then the file is claimed and
/// written in one pass — a panicking suite leaves no half-written file.
pub fn run(bcfg: &BenchConfig) -> BenchRun {
    let mut exp = bcfg.exp.clone();
    exp.journal = false; // a bench must re-measure, never resume
    std::fs::create_dir_all(&exp.out_dir)
        .unwrap_or_else(|e| panic!("cannot create {}: {e}", exp.out_dir.display()));
    let defs: Vec<&'static SuiteDef> =
        bcfg.suites.iter().map(|n| suite_def(n)).collect();

    let mut records: Vec<Record> = vec![header_record(&defs, bcfg.dry_run, &exp)];
    for def in &defs {
        match def.kind {
            SuiteKind::Cells => {
                cell_suite_records(def.name, &exp, bcfg.dry_run, &mut records);
            }
            SuiteKind::Micro => {
                records.extend(micro_suite_records(def.name, bcfg.dry_run));
            }
        }
    }
    for rec in &records {
        if let Err(e) = validate_record(rec) {
            panic!("internal: emitting invalid bench record ({e}): {rec:?}");
        }
    }

    let (mut file, path, run_no) = allocate_bench_file(&exp.out_dir);
    for rec in &records {
        let pairs: Vec<(&str, Json)> =
            rec.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        writeln!(file, "{}", json::obj_to_line(&pairs))
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    }
    file.flush().unwrap_or_else(|e| panic!("flushing {}: {e}", path.display()));
    BenchRun {
        path,
        run_no,
        records: records.len(),
    }
}

/// Entry point for the thin `benches/bench_*.rs` wrappers: run one
/// suite in quick mode against its historical `results/bench_<suite>`
/// directory.
pub fn bench_binary_main(suite: &str) {
    let mut exp = quick_exp_config();
    exp.out_dir = PathBuf::from(format!("results/bench_{suite}"));
    let bcfg = BenchConfig {
        suites: vec![suite.to_string()],
        dry_run: false,
        exp,
    };
    let out = run(&bcfg);
    println!(
        "bench {suite}: {} record(s) -> {}",
        out.records,
        out.path.display()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_registry_covers_every_bench_target_uniquely() {
        let defs = suite_defs();
        assert_eq!(defs.len(), 9, "one suite per benches/bench_*.rs target");
        let mut names: Vec<&str> = defs.iter().map(|d| d.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 10, "suite names must be unique");
        let mut replaces: Vec<&str> = defs.iter().map(|d| d.replaces).collect();
        replaces.sort_unstable();
        replaces.dedup();
        assert_eq!(replaces.len(), 10, "each suite subsumes a distinct target");
        assert!(replaces.iter().all(|r| r.starts_with("bench_")));
    }

    #[test]
    fn resolve_suite_names_handles_groups_and_lists() {
        assert_eq!(resolve_suite_names("all").len(), 10);
        let cells = resolve_suite_names("cells");
        assert_eq!(cells, vec!["table4", "fig2", "fig3", "fig4", "fig5"]);
        let micro = resolve_suite_names("micro");
        assert_eq!(micro, vec!["gendst", "automl", "entropy", "runtime", "pareto"]);
        assert_eq!(resolve_suite_names("fig3, gendst"), vec!["fig3", "gendst"]);
    }

    #[test]
    #[should_panic(expected = "unknown bench suite")]
    fn resolve_suite_names_rejects_typos() {
        resolve_suite_names("table5");
    }

    #[test]
    fn bench_file_names_roundtrip_and_reject_garbage() {
        assert_eq!(bench_file_name(7), "BENCH_7.json");
        assert_eq!(parse_bench_file_name("BENCH_7.json"), Some(7));
        assert_eq!(parse_bench_file_name("BENCH_123.json"), Some(123));
        for bad in ["BENCH_.json", "BENCH_x.json", "bench_1.json", "BENCH_1.jsonl", "notes.txt"] {
            assert_eq!(parse_bench_file_name(bad), None, "{bad}");
        }
    }

    #[test]
    fn run_numbering_is_monotone_over_existing_files() {
        let dir = std::env::temp_dir().join("substrat_bench_numbering_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(next_run_number(&dir), 1, "empty dir starts at 1");
        std::fs::write(dir.join("BENCH_9.json"), "sentinel").unwrap();
        std::fs::write(dir.join("BENCH_notanumber.json"), "ignored").unwrap();
        assert_eq!(next_run_number(&dir), 10);
        let (_, path, n) = allocate_bench_file(&dir);
        assert_eq!(n, 10);
        assert!(path.ends_with("BENCH_10.json"));
        // the claimed file exists now, so the next allocation bumps past it
        assert_eq!(next_run_number(&dir), 11);
        assert_eq!(
            std::fs::read_to_string(dir.join("BENCH_9.json")).unwrap(),
            "sentinel",
            "existing runs are never clobbered"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn emitted_record_shapes_validate_and_mutations_fail() {
        let header = header_record(
            &suite_defs().iter().collect::<Vec<_>>(),
            true,
            &quick_exp_config(),
        );
        validate_record(&header).unwrap();
        let suite = suite_record("table4", 8, 0.0, 0.0, true);
        validate_record(&suite).unwrap();
        let cell = cell_record(
            "table4",
            &Cell::new("D2", "gendst", SearcherKind::Smbo, 0),
            "deadbeef",
            "table2:D2",
            "cafef00d",
            TimingMode::Wall,
            None,
        );
        validate_record(&cell).unwrap();
        validate_record(&stub_micro("entropy", "native subset_entropy 114x6")).unwrap();
        validate_record(&counter_record("gendst", "memo_hit_rate x", 0.5, true)).unwrap();

        // unknown fields are fine (additive versioning rule)...
        let mut extended = suite.clone();
        extended.push(str_field("future_field", "ok"));
        validate_record(&extended).unwrap();
        // ...but a missing required field, a wrong type, or an unknown
        // record kind is not
        let missing: Record =
            cell.iter().filter(|(k, _)| k != "cfg").cloned().collect();
        assert!(validate_record(&missing).is_err());
        let mut wrong_type = cell.clone();
        for (k, v) in &mut wrong_type {
            if k == "rep" {
                *v = Json::Str("zero".into());
            }
        }
        assert!(validate_record(&wrong_type).is_err());
        assert!(validate_record(&[str_field("record", "surprise")]).is_err());
        let mut frac_rep = cell;
        for (k, v) in &mut frac_rep {
            if k == "rep" {
                *v = Json::Num(0.5);
            }
        }
        assert!(validate_record(&frac_rep).is_err());
    }

    #[test]
    fn dry_cell_suite_expands_with_real_fingerprints() {
        let exp = ExpConfig {
            reps: 1,
            searchers: vec![SearcherKind::Random],
            datasets: vec!["D2".into()],
            ..Default::default()
        };
        let mut out = Vec::new();
        cell_suite_records("table4", &exp, true, &mut out);
        // 8 strategies x 1 dataset x 1 rep x 1 searcher + the suite total
        assert_eq!(out.len(), 9);
        for rec in &out {
            validate_record(rec).unwrap();
        }
        let fp = json::get(&out[0], "cell").unwrap().as_str().unwrap();
        assert_eq!(fp.len(), 32, "hex128 cell fingerprint");
        assert_eq!(
            json::get(&out[0], "src").unwrap().as_str(),
            Some("table2:D2"),
            "registry sources fingerprint by symbol"
        );
    }

    #[test]
    fn dry_micro_suites_emit_stub_records_only() {
        for name in ["gendst", "automl", "entropy", "runtime", "pareto"] {
            let recs = micro_suite_records(name, true);
            assert!(!recs.is_empty(), "{name}");
            for r in &recs {
                validate_record(r).unwrap_or_else(|e| panic!("{name}: {e}"));
                assert_eq!(json::get(r, "dry"), Some(&Json::Bool(true)));
                if json::get(r, "record").unwrap().as_str() == Some("micro") {
                    assert_eq!(json::get(r, "mean_ns").unwrap().as_f64(), Some(0.0));
                }
            }
        }
    }
}
