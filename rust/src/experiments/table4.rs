//! Table 4 — mean ± std Time-Reduction and Relative-Accuracy per
//! strategy per AutoML searcher, aggregated over all datasets and
//! repetitions. Regenerate with `substrat exp table4` or
//! `cargo bench --bench bench_table4`.

use crate::experiments::runner::{strategy_grid, Cell, Runner};
use crate::experiments::{paper_label, table4_strategy_names, ExpConfig, RunRecord};
use crate::util::stats;
use crate::util::table::{pct, Table};

/// The Table-4 cell grid: every strategy × (dataset × rep × searcher).
/// Shared with the bench trajectory (DESIGN.md §5.4) so `exp table4`
/// and `bench table4` expand the identical sweep.
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let strategies = table4_strategy_names();
    strategy_grid(cfg, &strategies)
}

/// Collect raw records for the given strategies across the full
/// (dataset × rep × searcher) grid through the shared cell scheduler
/// (DESIGN.md §5.2): contention-free timing, resumable journal.
pub fn collect_records(cfg: &ExpConfig, strategies: &[&str]) -> Vec<RunRecord> {
    let cells = strategy_grid(cfg, strategies);
    Runner::new(cfg)
        .run(&cells)
        .into_iter()
        .map(|o| o.record)
        .collect()
}

/// Aggregate records into the Table-4 layout.
pub fn aggregate(records: &[RunRecord], cfg: &ExpConfig) -> Table {
    let mut table = Table::new(vec![
        "Algorithm",
        "Searcher",
        "Time Reduction",
        "Rel. Acc.",
        "cells",
    ]);
    for strategy in table4_strategy_names() {
        for searcher in &cfg.searchers {
            let rows: Vec<&RunRecord> = records
                .iter()
                .filter(|r| r.strategy == strategy && r.searcher == searcher.name())
                .collect();
            if rows.is_empty() {
                continue;
            }
            let tr: Vec<f64> = rows.iter().map(|r| r.time_reduction()).collect();
            let ra: Vec<f64> = rows.iter().map(|r| r.relative_accuracy()).collect();
            table.push(vec![
                paper_label(strategy).to_string(),
                searcher.name().to_string(),
                pct(stats::mean(&tr), stats::std(&tr)),
                pct(stats::mean(&ra), stats::std(&ra)),
                rows.len().to_string(),
            ]);
        }
    }
    table
}

/// Raw records as CSV (for replotting / fig2 reuse).
pub fn records_csv(records: &[RunRecord]) -> Table {
    let mut t = Table::new(vec![
        "dataset",
        "strategy",
        "searcher",
        "rep",
        "time_full_s",
        "time_sub_s",
        "acc_full",
        "acc_sub",
        "time_reduction",
        "relative_accuracy",
        "final_config",
    ]);
    for r in records {
        t.push(vec![
            r.dataset.clone(),
            r.strategy.clone(),
            r.searcher.to_string(),
            r.rep.to_string(),
            format!("{:.4}", r.time_full_s),
            format!("{:.4}", r.time_sub_s),
            format!("{:.4}", r.acc_full),
            format!("{:.4}", r.acc_sub),
            format!("{:.4}", r.time_reduction()),
            format!("{:.4}", r.relative_accuracy()),
            r.final_desc.clone(),
        ]);
    }
    t
}

/// Full Table-4 driver: collect, aggregate, print, persist.
pub fn run(cfg: &ExpConfig) -> (Vec<RunRecord>, Table) {
    let strategies = table4_strategy_names();
    let records = collect_records(cfg, &strategies);
    let table = aggregate(&records, cfg);
    println!(
        "\n=== Table 4 (scale={}, reps={}, evals={}) ===",
        cfg.scale, cfg.reps, cfg.full_evals
    );
    println!("{}", table.to_aligned());
    let _ = records_csv(&records).write_csv(&cfg.out_dir.join("table4_records.csv"));
    let _ = table.write_csv(&cfg.out_dir.join("table4.csv"));

    // Figure 2 falls out of the same records (per-dataset smbo points) —
    // no second sweep needed
    let smbo_records: Vec<RunRecord> = records
        .iter()
        .filter(|r| r.searcher == "smbo")
        .cloned()
        .collect();
    if !smbo_records.is_empty() {
        let points = crate::experiments::fig2::per_dataset_points(&smbo_records);
        let counts = crate::experiments::fig2::above_bar_counts(&points);
        println!("=== Figure 2 (from the same records) ===");
        println!("{}", counts.to_aligned());
        let _ = points.write_csv(&cfg.out_dir.join("fig2_points.csv"));
        let _ = counts.write_csv(&cfg.out_dir.join("fig2_above_bar.csv"));
    }
    (records, table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automl::SearcherKind;

    #[test]
    fn aggregate_groups_correctly() {
        let cfg = ExpConfig {
            searchers: vec![SearcherKind::Smbo],
            ..Default::default()
        };
        let mk = |strategy: &str, tr_time: f64| RunRecord {
            dataset: "D1".into(),
            strategy: strategy.into(),
            searcher: "smbo",
            rep: 0,
            time_full_s: 10.0,
            time_sub_s: tr_time,
            acc_full: 1.0,
            acc_sub: 0.9,
            final_desc: String::new(),
        };
        let records = vec![mk("gendst", 2.0), mk("gendst", 4.0), mk("km", 1.0)];
        let t = aggregate(&records, &cfg);
        // gendst row: mean time reduction of 0.8 and 0.6 = 70%
        let row = t
            .rows
            .iter()
            .find(|r| r[0] == "SubStrat")
            .expect("SubStrat row");
        assert!(row[2].starts_with("70.00"), "{row:?}");
        assert_eq!(row[4], "2");
    }

    #[test]
    fn records_csv_layout() {
        let r = RunRecord {
            dataset: "D3".into(),
            strategy: "mab".into(),
            searcher: "gp",
            rep: 1,
            time_full_s: 5.0,
            time_sub_s: 1.0,
            acc_full: 0.8,
            acc_sub: 0.72,
            final_desc: String::new(),
        };
        let t = records_csv(&[r]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], "D3");
        assert_eq!(t.rows[0][8], "0.8000");
        assert_eq!(t.rows[0][9], "0.9000");
    }
}
