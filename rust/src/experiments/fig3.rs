//! Figure 3 — the SubStrat configuration skyline: alternative
//! (DST-size, fine-tune-budget) settings of SubStrat traded off against
//! IG-KM's settings in (time-reduction, relative-accuracy) space, keeping
//! only Pareto-optimal points (the "skyline" operator the paper cites).
//! Regenerate with `substrat exp fig3`.
//!
//! `substrat exp fig3 --skyline` is the §10 alternative: instead of
//! brute-forcing the size trade-off with one scalar search per
//! multiplier (each re-paying the whole search on the same data), ONE
//! multi-objective Gen-DST run per (dataset, rep) returns the entire
//! (fidelity, size, time) front — the brute-force grid stays as the
//! cross-check reference (see the dominance test below).

use crate::automl::SearcherKind;
use crate::data::registry::DataSource;
use crate::experiments::runner::{self, Cell, DstSpec, Runner};
use crate::experiments::{bench, prepare, ExpConfig};
use crate::gendst::pareto::{self, Objective};
use crate::gendst::{gen_dst, GenDstConfig};
use crate::measures::entropy::EntropyMeasure;
use crate::util::json::{self, Json};
use crate::util::stats;
use crate::util::table::Table;

/// The 2-D maximization skyline, shared with the general NSGA-II
/// machinery (one implementation; the equivalence is property-tested
/// in `gendst::pareto`).
pub use crate::gendst::pareto::skyline;

/// One configuration variant to place on the plane.
#[derive(Debug, Clone)]
pub struct Variant {
    pub label: String,
    pub strategy: &'static str,
    /// multipliers on the default (sqrt(N), 0.25 M)
    pub n_mult: f64,
    pub m_mult: f64,
    pub ft_frac: f64,
}

/// The variant grid: SubStrat settings 1..6 + IG-KM settings 1..3.
pub fn variants() -> Vec<Variant> {
    let mut v = Vec::new();
    let substrat_grid: &[(f64, f64, f64)] = &[
        (1.0, 1.0, 0.25),  // SubStrat-1: the paper default
        (0.5, 0.6, 0.15),  // SubStrat-2: the fast one
        (0.5, 1.0, 0.25),
        (2.0, 1.0, 0.25),
        (1.0, 2.0, 0.40),
        (0.25, 0.6, 0.10),
    ];
    for (i, &(n_mult, m_mult, ft_frac)) in substrat_grid.iter().enumerate() {
        v.push(Variant {
            label: format!("SubStrat-{}", i + 1),
            strategy: "gendst",
            n_mult,
            m_mult,
            ft_frac,
        });
    }
    let ig_grid: &[(f64, f64, f64)] = &[(1.0, 1.0, 0.25), (0.5, 0.6, 0.15), (2.0, 1.0, 0.25)];
    for (i, &(n_mult, m_mult, ft_frac)) in ig_grid.iter().enumerate() {
        v.push(Variant {
            label: format!("IG-KM-{}", i + 1),
            strategy: "ig-km",
            n_mult,
            m_mult,
            ft_frac,
        });
    }
    v
}

/// The fig3 cell grid: every variant × (dataset × rep), searcher pinned
/// to SMBO. Every (dataset, rep) pairs one Full-AutoML reference with
/// the whole variant grid; the scheduler shares the reference per
/// group. Shared with the bench trajectory (DESIGN.md §5.4).
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let vars = variants();
    let mut cells = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            for v in &vars {
                cells.push(
                    Cell::new(symbol.clone(), v.strategy, SearcherKind::Smbo, rep)
                        .with_dst(DstSpec::Mults {
                            n_mult: v.n_mult,
                            m_mult: v.m_mult,
                        })
                        .with_ft_frac(v.ft_frac)
                        .with_label(v.label.clone()),
                );
            }
        }
    }
    cells
}

pub fn run(cfg: &ExpConfig) -> Table {
    let vars = variants();
    let flat: Vec<(String, f64, f64)> = Runner::new(cfg)
        .run(&cells(cfg))
        .into_iter()
        .map(|o| {
            (
                o.cell.label().to_string(),
                o.record.time_reduction(),
                o.record.relative_accuracy(),
            )
        })
        .collect();
    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for v in &vars {
        let trs: Vec<f64> = flat
            .iter()
            .filter(|(l, _, _)| *l == v.label)
            .map(|&(_, tr, _)| tr)
            .collect();
        let ras: Vec<f64> = flat
            .iter()
            .filter(|(l, _, _)| *l == v.label)
            .map(|&(_, _, ra)| ra)
            .collect();
        points.push((v.label.clone(), stats::mean(&trs), stats::mean(&ras)));
    }
    let sky = skyline(&points);

    let mut t = Table::new(vec!["config", "time_reduction", "relative_accuracy", "on_skyline"]);
    for (label, tr, ra) in &points {
        t.push(vec![
            label.clone(),
            format!("{tr:.4}"),
            format!("{ra:.4}"),
            sky.iter().any(|(l, _, _)| l == label).to_string(),
        ]);
    }
    println!("\n=== Figure 3: SubStrat settings skyline ===");
    println!("{}", t.to_aligned());
    let _ = t.write_csv(&cfg.out_dir.join("fig3_skyline.csv"));
    t
}

/// The `--skyline` objective triple: an explicit non-scalar
/// `--objectives` wins; the scalar default is upgraded, because a
/// one-point front cannot sweep the trade-off.
pub fn skyline_config(cfg: &ExpConfig) -> ExpConfig {
    let mut mo = cfg.clone();
    if pareto::scalar_mode(&mo.objectives) {
        mo.objectives = vec![
            Objective::Fidelity,
            Objective::SubsetSize,
            Objective::DownstreamTime,
        ];
    }
    mo
}

/// The skyline cell grid: ONE multi-objective search per (dataset,
/// rep) — against the 6-cells-per-group multiplier grid of [`cells`].
pub fn skyline_cells(cfg: &ExpConfig) -> Vec<Cell> {
    let mut out = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            out.push(
                Cell::new(symbol.clone(), "gendst", SearcherKind::Smbo, rep)
                    .with_label("skyline"),
            );
        }
    }
    out
}

/// The engine shape behind one skyline cell: the cell's pinned island
/// count and objective vector, seeded exactly like the strategy cells
/// (`experiments::strategy_search`'s `^ 0x44` derivation).
fn skyline_engine(cfg: &ExpConfig, rep: usize) -> GenDstConfig {
    GenDstConfig {
        objectives: cfg.objectives.clone(),
        islands: cfg.islands.max(1),
        threads: cfg.threads,
        seed: cfg.seed ^ 0x44 ^ rep as u64,
        ..Default::default()
    }
}

/// `exp fig3 --skyline`: the single-run skyline. Dry mode expands,
/// fingerprints, serializes, and validates every cell as a `bench-v1`
/// record — the same pipeline `bench` uses — so the mode is
/// integration-testable without paying a search. Real mode runs one
/// multi-objective search per cell and tabulates the front (one row
/// per operating point) into `fig3_front.csv`.
pub fn run_skyline(cfg: &ExpConfig, dry: bool) -> Table {
    let mo = skyline_config(cfg);
    let cells = skyline_cells(&mo);
    if dry {
        let cfg_fp = runner::config_fingerprint(&mo);
        let mut records: Vec<bench::Record> = Vec::new();
        for c in &cells {
            let src = DataSource::parse(&c.symbol).fingerprint();
            let fp = c.fingerprint(&mo, &cfg_fp, &src);
            records.push(bench::cell_record(
                "fig3-skyline",
                c,
                &fp,
                &src,
                &cfg_fp,
                mo.timing,
                None,
            ));
        }
        records.push(bench::suite_record("fig3-skyline", cells.len(), 0.0, 0.0, true));
        let mut t = Table::new(vec!["record"]);
        for rec in &records {
            bench::validate_record(rec)
                .unwrap_or_else(|e| panic!("invalid skyline record ({e}): {rec:?}"));
            let pairs: Vec<(&str, Json)> =
                rec.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
            t.push(vec![json::obj_to_line(&pairs)]);
        }
        println!("\n=== Figure 3 (skyline, dry): {} cell(s) expanded ===", cells.len());
        return t;
    }
    let mut header = vec!["dataset", "rep", "rows", "cols"];
    header.extend(mo.objectives.iter().map(|o| o.name()));
    let mut t = Table::new(header);
    for c in &cells {
        let prep = prepare(&c.symbol, &mo, c.rep);
        let (n, m) =
            crate::gendst::default_dst_size(prep.train.n_rows, prep.train.n_cols());
        let engine = skyline_engine(&mo, c.rep);
        let res = gen_dst(&prep.train, &prep.codes, &EntropyMeasure, n, m, &engine);
        for p in &res.front {
            let mut row = vec![
                c.symbol.clone(),
                c.rep.to_string(),
                p.dst.rows.len().to_string(),
                p.dst.cols.len().to_string(),
            ];
            row.extend(p.objectives.iter().map(|v| format!("{v:.6}")));
            t.push(row);
        }
    }
    println!("\n=== Figure 3: single-run skyline front ===");
    println!("{}", t.to_aligned());
    let _ = t.write_csv(&cfg.out_dir.join("fig3_front.csv"));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skyline_removes_dominated() {
        let pts = vec![
            ("a".to_string(), 0.8, 0.98),
            ("b".to_string(), 0.9, 0.96),
            ("c".to_string(), 0.7, 0.90), // dominated by both
            ("d".to_string(), 0.85, 0.97),
        ];
        let sky = skyline(&pts);
        let labels: Vec<&str> = sky.iter().map(|(l, _, _)| l.as_str()).collect();
        assert!(labels.contains(&"a"));
        assert!(labels.contains(&"b"));
        assert!(labels.contains(&"d"));
        assert!(!labels.contains(&"c"));
    }

    #[test]
    fn variant_grid_has_default_first() {
        let v = variants();
        assert_eq!(v[0].label, "SubStrat-1");
        assert_eq!(v[0].n_mult, 1.0);
        assert!(v.iter().any(|x| x.strategy == "ig-km"));
    }

    #[test]
    fn skyline_keeps_single_point() {
        let pts = vec![("only".to_string(), 0.5, 0.5)];
        assert_eq!(skyline(&pts).len(), 1);
    }

    #[test]
    fn skyline_config_upgrades_scalar_and_respects_explicit_objectives() {
        let cfg = ExpConfig::default();
        let mo = skyline_config(&cfg);
        assert_eq!(mo.objectives.len(), 3, "scalar default upgrades to the triple");
        let explicit = ExpConfig {
            objectives: vec![Objective::Fidelity, Objective::SubsetSize],
            ..ExpConfig::default()
        };
        assert_eq!(skyline_config(&explicit).objectives.len(), 2, "explicit wins");
    }

    #[test]
    fn skyline_dry_run_expands_validated_bench_records() {
        // acceptance: `exp fig3 --skyline` (dry) expands, fingerprints,
        // and serializes valid bench-v1 records — one per (dataset,
        // rep) cell plus the suite total
        let cfg = ExpConfig {
            reps: 2,
            datasets: vec!["D2".into(), "D3".into()],
            ..Default::default()
        };
        let t = run_skyline(&cfg, true);
        assert_eq!(t.rows.len(), 5, "4 cells + 1 suite record");
        for row in &t.rows {
            let rec = json::parse_line(&row[0])
                .unwrap_or_else(|| panic!("unparseable record: {}", row[0]));
            bench::validate_record(&rec).unwrap();
        }
        // every skyline cell fingerprints under the MO config, never
        // the scalar one — the two must not share journal keys
        let scalar_fp = runner::config_fingerprint(&cfg);
        let mo_fp = runner::config_fingerprint(&skyline_config(&cfg));
        assert_ne!(scalar_fp, mo_fp);
        assert!(t.rows[0][0].contains(&mo_fp));
        assert!(!t.rows[0][0].contains(&scalar_fp));
    }

    #[test]
    fn one_run_front_weakly_dominates_the_brute_force_grid() {
        // acceptance: the single multi-objective search subsumes the
        // multiplier sweep — for every point the brute-force grid
        // produces (one scalar search per ladder size, same data, same
        // per-size budget shape fig3 uses at smoke scale), some front
        // point is at least as good in every objective. The MO run
        // gets the budget the grid spends in total; the grid pays it
        // per size.
        use crate::data::registry;
        use crate::data::CodeMatrix;
        let f = registry::load("D2", 0.05, 11); // 765 x 5
        let codes = CodeMatrix::from_frame(&f);
        let objectives = vec![
            Objective::Fidelity,
            Objective::SubsetSize,
            Objective::DownstreamTime,
        ];
        let (n, m) = crate::gendst::default_dst_size(f.n_rows, f.n_cols());
        let ladder = pareto::ladder_sizes(n, m, f.n_rows, f.n_cols());
        let mut grid_points: Vec<Vec<f64>> = Vec::new();
        for &(gn, gm) in &ladder {
            let cfg = GenDstConfig {
                generations: 2,
                population: 8,
                seed: 7,
                ..Default::default()
            };
            let res = gen_dst(&f, &codes, &EntropyMeasure, gn, gm, &cfg);
            grid_points.push(pareto::objective_vector(
                res.loss,
                res.dst.rows.len(),
                res.dst.cols.len(),
                f.n_rows,
                f.n_cols(),
                &objectives,
            ));
        }
        let mo_cfg = GenDstConfig {
            generations: 40,
            population: 72,
            objectives: objectives.clone(),
            seed: 7,
            ..Default::default()
        };
        let res = gen_dst(&f, &codes, &EntropyMeasure, n, m, &mo_cfg);
        for (i, g) in grid_points.iter().enumerate() {
            let covered = res.front.iter().any(|p| {
                p.objectives.iter().zip(g).all(|(a, b)| *a <= b + 1e-12)
            });
            assert!(
                covered,
                "grid point {i} {:?} ({g:?}) not weakly dominated by the front ({:?})",
                ladder[i],
                res.front.iter().map(|p| p.objectives.clone()).collect::<Vec<_>>()
            );
        }
    }
}
