//! Figure 3 — the SubStrat configuration skyline: alternative
//! (DST-size, fine-tune-budget) settings of SubStrat traded off against
//! IG-KM's settings in (time-reduction, relative-accuracy) space, keeping
//! only Pareto-optimal points (the "skyline" operator the paper cites).
//! Regenerate with `substrat exp fig3`.

use crate::automl::SearcherKind;
use crate::experiments::runner::{Cell, DstSpec, Runner};
use crate::experiments::ExpConfig;
use crate::util::stats;
use crate::util::table::Table;

/// One configuration variant to place on the plane.
#[derive(Debug, Clone)]
pub struct Variant {
    pub label: String,
    pub strategy: &'static str,
    /// multipliers on the default (sqrt(N), 0.25 M)
    pub n_mult: f64,
    pub m_mult: f64,
    pub ft_frac: f64,
}

/// The variant grid: SubStrat settings 1..6 + IG-KM settings 1..3.
pub fn variants() -> Vec<Variant> {
    let mut v = Vec::new();
    let substrat_grid: &[(f64, f64, f64)] = &[
        (1.0, 1.0, 0.25),  // SubStrat-1: the paper default
        (0.5, 0.6, 0.15),  // SubStrat-2: the fast one
        (0.5, 1.0, 0.25),
        (2.0, 1.0, 0.25),
        (1.0, 2.0, 0.40),
        (0.25, 0.6, 0.10),
    ];
    for (i, &(n_mult, m_mult, ft_frac)) in substrat_grid.iter().enumerate() {
        v.push(Variant {
            label: format!("SubStrat-{}", i + 1),
            strategy: "gendst",
            n_mult,
            m_mult,
            ft_frac,
        });
    }
    let ig_grid: &[(f64, f64, f64)] = &[(1.0, 1.0, 0.25), (0.5, 0.6, 0.15), (2.0, 1.0, 0.25)];
    for (i, &(n_mult, m_mult, ft_frac)) in ig_grid.iter().enumerate() {
        v.push(Variant {
            label: format!("IG-KM-{}", i + 1),
            strategy: "ig-km",
            n_mult,
            m_mult,
            ft_frac,
        });
    }
    v
}

/// Keep only points not strictly dominated in (time_red, rel_acc).
pub fn skyline(points: &[(String, f64, f64)]) -> Vec<(String, f64, f64)> {
    points
        .iter()
        .filter(|(_, tr, ra)| {
            !points
                .iter()
                .any(|(_, tr2, ra2)| tr2 >= tr && ra2 >= ra && (tr2 > tr || ra2 > ra))
        })
        .cloned()
        .collect()
}

/// The fig3 cell grid: every variant × (dataset × rep), searcher pinned
/// to SMBO. Every (dataset, rep) pairs one Full-AutoML reference with
/// the whole variant grid; the scheduler shares the reference per
/// group. Shared with the bench trajectory (DESIGN.md §5.4).
pub fn cells(cfg: &ExpConfig) -> Vec<Cell> {
    let vars = variants();
    let mut cells = Vec::new();
    for symbol in &cfg.datasets {
        for rep in 0..cfg.reps {
            for v in &vars {
                cells.push(
                    Cell::new(symbol.clone(), v.strategy, SearcherKind::Smbo, rep)
                        .with_dst(DstSpec::Mults {
                            n_mult: v.n_mult,
                            m_mult: v.m_mult,
                        })
                        .with_ft_frac(v.ft_frac)
                        .with_label(v.label.clone()),
                );
            }
        }
    }
    cells
}

pub fn run(cfg: &ExpConfig) -> Table {
    let vars = variants();
    let flat: Vec<(String, f64, f64)> = Runner::new(cfg)
        .run(&cells(cfg))
        .into_iter()
        .map(|o| {
            (
                o.cell.label().to_string(),
                o.record.time_reduction(),
                o.record.relative_accuracy(),
            )
        })
        .collect();
    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for v in &vars {
        let trs: Vec<f64> = flat
            .iter()
            .filter(|(l, _, _)| *l == v.label)
            .map(|&(_, tr, _)| tr)
            .collect();
        let ras: Vec<f64> = flat
            .iter()
            .filter(|(l, _, _)| *l == v.label)
            .map(|&(_, _, ra)| ra)
            .collect();
        points.push((v.label.clone(), stats::mean(&trs), stats::mean(&ras)));
    }
    let sky = skyline(&points);

    let mut t = Table::new(vec!["config", "time_reduction", "relative_accuracy", "on_skyline"]);
    for (label, tr, ra) in &points {
        t.push(vec![
            label.clone(),
            format!("{tr:.4}"),
            format!("{ra:.4}"),
            sky.iter().any(|(l, _, _)| l == label).to_string(),
        ]);
    }
    println!("\n=== Figure 3: SubStrat settings skyline ===");
    println!("{}", t.to_aligned());
    let _ = t.write_csv(&cfg.out_dir.join("fig3_skyline.csv"));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skyline_removes_dominated() {
        let pts = vec![
            ("a".to_string(), 0.8, 0.98),
            ("b".to_string(), 0.9, 0.96),
            ("c".to_string(), 0.7, 0.90), // dominated by both
            ("d".to_string(), 0.85, 0.97),
        ];
        let sky = skyline(&pts);
        let labels: Vec<&str> = sky.iter().map(|(l, _, _)| l.as_str()).collect();
        assert!(labels.contains(&"a"));
        assert!(labels.contains(&"b"));
        assert!(labels.contains(&"d"));
        assert!(!labels.contains(&"c"));
    }

    #[test]
    fn variant_grid_has_default_first() {
        let v = variants();
        assert_eq!(v[0].label, "SubStrat-1");
        assert_eq!(v[0].n_mult, 1.0);
        assert!(v.iter().any(|x| x.strategy == "ig-km"));
    }

    #[test]
    fn skyline_keeps_single_point() {
        let pts = vec![("only".to_string(), 0.5, 0.5)];
        assert_eq!(skyline(&pts).len(), 1);
    }
}
