//! SubStrat: a subset-based strategy for faster AutoML (VLDB 2022) —
//! full-system reproduction on a Rust + JAX + Pallas three-layer stack.
//!
//! Layer map (DESIGN.md):
//! * L3 (this crate): Gen-DST genetic search, the AutoML substrate, the
//!   10 baseline subset strategies, the SubStrat orchestrator, and the
//!   experiment harness reproducing every table/figure in the paper.
//! * L2/L1 (python/, build-time only): JAX graphs + the Pallas entropy
//!   kernel, AOT-lowered to `artifacts/*.hlo.txt` and executed here via
//!   PJRT (`runtime`).

pub mod automl;
pub mod baselines;
pub mod data;
pub mod experiments;
pub mod gendst;
pub mod measures;
pub mod models;
pub mod runtime;
pub mod substrat;
pub mod util;
