//! SubStrat: a subset-based strategy for faster AutoML (VLDB 2022) —
//! full-system reproduction on a Rust + JAX + Pallas three-layer stack.
//!
//! Start with the repo-root `README.md` for the quickstart and
//! `DESIGN.md` for the architecture; the layer map below is the short
//! version of DESIGN.md §2.
//!
//! Layer map (DESIGN.md §2):
//! * L3 (this crate): Gen-DST genetic search with the incremental +
//!   parallel fitness engine ([`gendst::fitness`], DESIGN.md §4.4), the
//!   AutoML substrate, the baseline subset strategies, the SubStrat
//!   orchestrator, and the experiment harness reproducing every
//!   table/figure in the paper.
//! * L2/L1 (python/, build-time only): JAX graphs + the Pallas entropy
//!   kernel, AOT-lowered to `artifacts/*.hlo.txt` and executed here via
//!   PJRT (`runtime`).

pub mod analysis;
pub mod automl;
pub mod baselines;
pub mod data;
pub mod experiments;
pub mod gendst;
pub mod measures;
pub mod models;
pub mod runtime;
pub mod substrat;
pub mod util;
