//! Dataset entropy (paper Def. 3.4, sign-corrected per Example 3.5):
//! the mean over columns of the Shannon entropy of each column's value
//! frequency distribution. This is the native (CPU) twin of the L1
//! Pallas kernel; `python/tests/test_kernel.py` pins both to the paper's
//! worked example.

use crate::data::binning::K_BINS;
use crate::data::{CodeMatrix, Frame};
use crate::measures::DatasetMeasure;

/// Shannon entropy (bits) of a histogram with total count `n`.
#[inline]
pub fn entropy_of_counts(counts: &[u32], n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let n = n as f64;
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / n;
            h -= p * p.log2();
        }
    }
    h
}

/// Entropy of one column over the given rows (stack histogram).
#[inline]
pub fn column_entropy(codes: &CodeMatrix, col: usize, rows: &[u32]) -> f64 {
    let mut counts = [0u32; K_BINS];
    let column = codes.column(col);
    for &r in rows {
        counts[column[r as usize] as usize] += 1;
    }
    entropy_of_counts(&counts, rows.len())
}

/// Entropy of one column over ALL rows (no index indirection — used for
/// the one-time H(D) computation on large datasets).
#[inline]
pub fn column_entropy_full(codes: &CodeMatrix, col: usize) -> f64 {
    let mut counts = [0u32; K_BINS];
    for &c in codes.column(col) {
        counts[c as usize] += 1;
    }
    entropy_of_counts(&counts, codes.n_rows)
}

/// Mean column entropy of the subset D[rows, cols].
pub fn subset_entropy(codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
    if cols.is_empty() {
        return 0.0;
    }
    let sum: f64 = cols
        .iter()
        .map(|&c| column_entropy(codes, c as usize, rows))
        .sum();
    sum / cols.len() as f64
}

/// Mean column entropy of the full dataset (one pass, no row indices).
pub fn full_entropy(codes: &CodeMatrix) -> f64 {
    if codes.n_cols == 0 {
        return 0.0;
    }
    let sum: f64 = (0..codes.n_cols)
        .map(|c| column_entropy_full(codes, c))
        .sum();
    sum / codes.n_cols as f64
}

/// Per-column entropies over all rows (column profile of D).
pub fn column_profile(codes: &CodeMatrix) -> Vec<f64> {
    (0..codes.n_cols)
        .map(|c| column_entropy_full(codes, c))
        .collect()
}

/// The paper's default measure.
pub struct EntropyMeasure;

impl DatasetMeasure for EntropyMeasure {
    fn name(&self) -> &'static str {
        "entropy"
    }

    fn of_subset(&self, _frame: &Frame, codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
        subset_entropy(codes, rows, cols)
    }

    fn of_full(&self, _frame: &Frame, codes: &CodeMatrix) -> f64 {
        full_entropy(codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Frame};

    /// The paper's Table 1 flight-review dataset.
    pub fn paper_table1() -> Frame {
        Frame::new(
            "flight",
            vec![
                Column::numeric(
                    "age",
                    vec![25., 62., 25., 41., 27., 41., 20., 25., 13., 52.],
                ),
                Column::categorical("gender", vec![1., 1., 0., 0., 1., 1., 0., 0., 0., 1.]),
                Column::numeric(
                    "distance",
                    vec![460., 460., 460., 460., 460., 1061., 1061., 1061., 1061., 1061.],
                ),
                Column::numeric("delay", vec![18., 0., 40., 0., 0., 0., 0., 51., 0., 0.]),
                Column::categorical("satisfied", vec![1., 0., 1., 1., 1., 0., 0., 0., 1., 1.]),
            ],
            4,
        )
    }

    #[test]
    fn paper_example_3_5_full() {
        // H(D) = (2.65 + 1 + 1 + 1.4 + 0.97) / 5 = 1.395
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let profile = column_profile(&codes);
        let expect = [2.646, 1.0, 1.0, 1.357, 0.971];
        for (got, want) in profile.iter().zip(expect) {
            assert!((got - want).abs() < 5e-3, "{got} vs {want}");
        }
        assert!((full_entropy(&codes) - 1.395).abs() < 5e-3);
    }

    #[test]
    fn paper_example_3_5_green_and_red_subsets() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        // green: rows (1,2,3,6,8) 1-indexed, cols (age, delay, satisfied)
        let green = subset_entropy(&codes, &[0, 1, 2, 5, 7], &[0, 3, 4]);
        assert!((green - 1.42).abs() < 6e-3, "green={green}");
        // red: rows (4,5,7,9,10), cols (gender, distance, satisfied)
        let red = subset_entropy(&codes, &[3, 4, 6, 8, 9], &[1, 2, 4]);
        assert!((red - 0.89).abs() < 2e-2, "red={red}");
        // green preserves H(D)=1.395 better than red
        let hd = full_entropy(&codes);
        assert!((green - hd).abs() < (red - hd).abs());
    }

    #[test]
    fn entropy_of_counts_cases() {
        assert_eq!(entropy_of_counts(&[0, 0], 0), 0.0);
        assert!((entropy_of_counts(&[5, 5], 10) - 1.0).abs() < 1e-12);
        assert!((entropy_of_counts(&[10], 10)).abs() < 1e-12);
        let h4 = entropy_of_counts(&[2, 2, 2, 2], 8);
        assert!((h4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn subset_entropy_row_col_order_invariant() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let a = subset_entropy(&codes, &[0, 1, 2, 5, 7], &[0, 3, 4]);
        let b = subset_entropy(&codes, &[7, 0, 5, 2, 1], &[4, 0, 3]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn full_matches_subset_with_all_indices() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let rows: Vec<u32> = (0..10).collect();
        let cols: Vec<u32> = (0..5).collect();
        assert!((full_entropy(&codes) - subset_entropy(&codes, &rows, &cols)).abs() < 1e-12);
    }

    #[test]
    fn empty_cols_zero() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        assert_eq!(subset_entropy(&codes, &[0, 1], &[]), 0.0);
    }
}
