//! Dataset entropy (paper Def. 3.4, sign-corrected per Example 3.5):
//! the mean over columns of the Shannon entropy of each column's value
//! frequency distribution. This is the native (CPU) twin of the L1
//! Pallas kernel; `python/tests/test_kernel.py` pins both to the paper's
//! worked example.

use crate::data::binning::K_BINS;
use crate::data::{CodeMatrix, Frame};
use crate::measures::DatasetMeasure;

/// Shannon entropy (bits) of a histogram with total count `n`.
#[inline]
pub fn entropy_of_counts(counts: &[u32], n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let n = n as f64;
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / n;
            h -= p * p.log2();
        }
    }
    h
}

/// Value-frequency histogram of one column over the given rows: the
/// primitive the incremental Gen-DST fitness engine caches per candidate
/// column (DESIGN.md §4.4). Full build is O(|rows|).
#[inline]
pub fn column_hist(codes: &CodeMatrix, col: usize, rows: &[u32]) -> [u32; K_BINS] {
    let mut counts = [0u32; K_BINS];
    let column = codes.column(col);
    for &r in rows {
        counts[column[r as usize] as usize] += 1;
    }
    counts
}

/// Delta-update a cached column histogram after a row swap
/// (`old_row` left the subset, `new_row` entered it): O(1) instead of an
/// O(|rows|) rebuild. `hist` must currently count a row set containing
/// `old_row` and not `new_row`; counts stay exact because they are
/// integers (no float drift across arbitrarily long update chains).
#[inline]
pub fn hist_swap_row(hist: &mut [u32; K_BINS], column: &[u16], old_row: u32, new_row: u32) {
    hist[column[old_row as usize] as usize] -= 1;
    hist[column[new_row as usize] as usize] += 1;
}

/// Entropy of one column over the given rows (stack histogram).
#[inline]
pub fn column_entropy(codes: &CodeMatrix, col: usize, rows: &[u32]) -> f64 {
    let counts = column_hist(codes, col, rows);
    entropy_of_counts(&counts, rows.len())
}

/// Entropy of one column over ALL rows (no index indirection — used for
/// the one-time H(D) computation on large datasets).
#[inline]
pub fn column_entropy_full(codes: &CodeMatrix, col: usize) -> f64 {
    let mut counts = [0u32; K_BINS];
    for &c in codes.column(col) {
        counts[c as usize] += 1;
    }
    entropy_of_counts(&counts, codes.n_rows)
}

/// Mean column entropy of the subset D[rows, cols] (paper Def. 3.4).
///
/// This is the from-scratch reference the incremental fitness engine is
/// property-tested against; per-column entropies depend only on the
/// index *sets*, so the result is row/column-order invariant.
///
/// ```
/// use substrat::data::{registry, CodeMatrix};
/// use substrat::measures::entropy::{full_entropy, subset_entropy};
///
/// let frame = registry::load("D2", 0.05, 0);
/// let codes = CodeMatrix::from_frame(&frame);
/// let rows: Vec<u32> = (0..frame.n_rows as u32).collect();
/// let cols: Vec<u32> = (0..frame.n_cols() as u32).collect();
/// // the full index sets reproduce F(D) exactly
/// let h = subset_entropy(&codes, &rows, &cols);
/// assert!((h - full_entropy(&codes)).abs() < 1e-12);
/// ```
pub fn subset_entropy(codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
    if cols.is_empty() {
        return 0.0;
    }
    let sum: f64 = cols
        .iter()
        .map(|&c| column_entropy(codes, c as usize, rows))
        .sum();
    sum / cols.len() as f64
}

/// Mean column entropy of the full dataset (one pass, no row indices).
pub fn full_entropy(codes: &CodeMatrix) -> f64 {
    if codes.n_cols == 0 {
        return 0.0;
    }
    let sum: f64 = (0..codes.n_cols)
        .map(|c| column_entropy_full(codes, c))
        .sum();
    sum / codes.n_cols as f64
}

/// Per-column entropies over all rows (column profile of D).
pub fn column_profile(codes: &CodeMatrix) -> Vec<f64> {
    (0..codes.n_cols)
        .map(|c| column_entropy_full(codes, c))
        .collect()
}

/// The paper's default measure.
pub struct EntropyMeasure;

impl DatasetMeasure for EntropyMeasure {
    fn name(&self) -> &'static str {
        "entropy"
    }

    fn of_subset(&self, _frame: &Frame, codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
        subset_entropy(codes, rows, cols)
    }

    fn of_full(&self, _frame: &Frame, codes: &CodeMatrix) -> f64 {
        full_entropy(codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Frame};

    /// The paper's Table 1 flight-review dataset.
    pub fn paper_table1() -> Frame {
        Frame::new(
            "flight",
            vec![
                Column::numeric(
                    "age",
                    vec![25., 62., 25., 41., 27., 41., 20., 25., 13., 52.],
                ),
                Column::categorical("gender", vec![1., 1., 0., 0., 1., 1., 0., 0., 0., 1.]),
                Column::numeric(
                    "distance",
                    vec![460., 460., 460., 460., 460., 1061., 1061., 1061., 1061., 1061.],
                ),
                Column::numeric("delay", vec![18., 0., 40., 0., 0., 0., 0., 51., 0., 0.]),
                Column::categorical("satisfied", vec![1., 0., 1., 1., 1., 0., 0., 0., 1., 1.]),
            ],
            4,
        )
    }

    #[test]
    fn paper_example_3_5_full() {
        // H(D) = (2.65 + 1 + 1 + 1.4 + 0.97) / 5 = 1.395
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let profile = column_profile(&codes);
        let expect = [2.646, 1.0, 1.0, 1.357, 0.971];
        for (got, want) in profile.iter().zip(expect) {
            assert!((got - want).abs() < 5e-3, "{got} vs {want}");
        }
        assert!((full_entropy(&codes) - 1.395).abs() < 5e-3);
    }

    #[test]
    fn paper_example_3_5_green_and_red_subsets() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        // green: rows (1,2,3,6,8) 1-indexed, cols (age, delay, satisfied)
        let green = subset_entropy(&codes, &[0, 1, 2, 5, 7], &[0, 3, 4]);
        assert!((green - 1.42).abs() < 6e-3, "green={green}");
        // red: rows (4,5,7,9,10), cols (gender, distance, satisfied)
        let red = subset_entropy(&codes, &[3, 4, 6, 8, 9], &[1, 2, 4]);
        assert!((red - 0.89).abs() < 2e-2, "red={red}");
        // green preserves H(D)=1.395 better than red
        let hd = full_entropy(&codes);
        assert!((green - hd).abs() < (red - hd).abs());
    }

    #[test]
    fn entropy_of_counts_cases() {
        assert_eq!(entropy_of_counts(&[0, 0], 0), 0.0);
        assert!((entropy_of_counts(&[5, 5], 10) - 1.0).abs() < 1e-12);
        assert!((entropy_of_counts(&[10], 10)).abs() < 1e-12);
        let h4 = entropy_of_counts(&[2, 2, 2, 2], 8);
        assert!((h4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn subset_entropy_row_col_order_invariant() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let a = subset_entropy(&codes, &[0, 1, 2, 5, 7], &[0, 3, 4]);
        let b = subset_entropy(&codes, &[7, 0, 5, 2, 1], &[4, 0, 3]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn full_matches_subset_with_all_indices() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let rows: Vec<u32> = (0..10).collect();
        let cols: Vec<u32> = (0..5).collect();
        assert!((full_entropy(&codes) - subset_entropy(&codes, &rows, &cols)).abs() < 1e-12);
    }

    #[test]
    fn hist_swap_row_matches_rebuild() {
        use crate::util::rng::Rng;
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let mut rows = rng.sample_distinct(10, 5);
            let col = rng.usize_below(5);
            let mut hist = column_hist(&codes, col, &rows);
            // swap a member row for a fresh one, delta-update the hist
            let slot = rng.usize_below(rows.len());
            let new = loop {
                let r = rng.u64_below(10) as u32;
                if !rows.contains(&r) {
                    break r;
                }
            };
            let old = rows[slot];
            rows[slot] = new;
            hist_swap_row(&mut hist, codes.column(col), old, new);
            assert_eq!(hist, column_hist(&codes, col, &rows));
            // and the entropy from the delta-updated hist is bit-identical
            assert_eq!(
                entropy_of_counts(&hist, rows.len()),
                column_entropy(&codes, col, &rows)
            );
        }
    }

    #[test]
    fn empty_cols_zero() {
        let f = paper_table1();
        let codes = CodeMatrix::from_frame(&f);
        assert_eq!(subset_entropy(&codes, &[0, 1], &[]), 0.0);
    }
}
