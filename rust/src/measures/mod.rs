//! Dataset measures `F : D -> R` (paper §3.1). The paper's default is
//! dataset entropy (Def. 3.4); §3.1 names p-norm, mean-correlation and
//! coefficient-of-variation as alternatives, all implemented here so the
//! Gen-DST optimizer stays measure-generic.

#![warn(missing_docs)]

pub mod entropy;
pub mod other;

use crate::data::{CodeMatrix, Frame};

/// A dataset characteristic evaluated on a (rows, cols) subset view.
/// Implementations must be pure and row/col-order invariant.
pub trait DatasetMeasure: Sync {
    /// Stable identifier used by [`by_name`] and the CLI.
    fn name(&self) -> &'static str;

    /// F(D[rows, cols]). `codes` is the binned view of `frame`; measures
    /// choose which representation they need.
    fn of_subset(&self, frame: &Frame, codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64;

    /// F(D) — default: the full index sets.
    fn of_full(&self, frame: &Frame, codes: &CodeMatrix) -> f64 {
        let rows: Vec<u32> = (0..frame.n_rows as u32).collect();
        let cols: Vec<u32> = (0..frame.n_cols() as u32).collect();
        self.of_subset(frame, codes, &rows, &cols)
    }
}

/// Construct a measure by CLI name.
pub fn by_name(name: &str) -> Box<dyn DatasetMeasure> {
    match name {
        "entropy" => Box::new(entropy::EntropyMeasure),
        "pnorm" => Box::new(other::PNormMeasure { p: 2.0 }),
        "mean-correlation" => Box::new(other::MeanCorrelationMeasure),
        "cv" => Box::new(other::CoefficientOfVariationMeasure),
        other => panic!("unknown measure {other:?} (entropy|pnorm|mean-correlation|cv)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Frame};

    #[test]
    fn by_name_resolves_all() {
        for n in ["entropy", "pnorm", "mean-correlation", "cv"] {
            assert_eq!(by_name(n).name(), n);
        }
    }

    #[test]
    #[should_panic(expected = "unknown measure")]
    fn by_name_rejects_unknown() {
        let _ = by_name("nope");
    }

    #[test]
    fn of_full_equals_subset_with_all_indices() {
        let f = Frame::new(
            "t",
            vec![
                Column::numeric("a", vec![1.0, 2.0, 3.0, 4.0]),
                Column::categorical("y", vec![0.0, 1.0, 0.0, 1.0]),
            ],
            1,
        );
        let codes = CodeMatrix::from_frame(&f);
        let m = by_name("entropy");
        let full = m.of_full(&f, &codes);
        let sub = m.of_subset(&f, &codes, &[0, 1, 2, 3], &[0, 1]);
        assert!((full - sub).abs() < 1e-12);
    }
}
