//! Alternative dataset measures named in paper §3.1: p-norm,
//! mean-correlation, coefficient of variation. They operate on the raw
//! frame values (not codes) since they are moment/shape statistics.

use crate::data::{CodeMatrix, Frame};
use crate::measures::DatasetMeasure;
use crate::util::stats;

fn subset_column(frame: &Frame, col: u32, rows: &[u32]) -> Vec<f64> {
    let v = &frame.columns[col as usize].values;
    rows.iter().map(|&r| v[r as usize] as f64).collect()
}

/// Mean per-column p-norm, normalized by row count so that subsets are
/// comparable to the full dataset: (Σ|x|^p / n)^(1/p) averaged over cols.
pub struct PNormMeasure {
    /// the norm order (the paper's example uses p = 2)
    pub p: f64,
}

impl DatasetMeasure for PNormMeasure {
    fn name(&self) -> &'static str {
        "pnorm"
    }

    fn of_subset(&self, frame: &Frame, _codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
        if cols.is_empty() || rows.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for &c in cols {
            let xs = subset_column(frame, c, rows);
            let s: f64 = xs.iter().map(|x| x.abs().powf(self.p)).sum();
            total += (s / rows.len() as f64).powf(1.0 / self.p);
        }
        total / cols.len() as f64
    }
}

/// Mean absolute pairwise Pearson correlation between the selected
/// columns — captures the dataset's dependence structure.
pub struct MeanCorrelationMeasure;

impl DatasetMeasure for MeanCorrelationMeasure {
    fn name(&self) -> &'static str {
        "mean-correlation"
    }

    fn of_subset(&self, frame: &Frame, _codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
        if cols.len() < 2 || rows.len() < 2 {
            return 0.0;
        }
        let columns: Vec<Vec<f64>> = cols
            .iter()
            .map(|&c| subset_column(frame, c, rows))
            .collect();
        let mut total = 0.0;
        let mut pairs = 0usize;
        for i in 0..columns.len() {
            for j in (i + 1)..columns.len() {
                total += stats::pearson(&columns[i], &columns[j]).abs();
                pairs += 1;
            }
        }
        total / pairs as f64
    }
}

/// Mean per-column coefficient of variation (std/|mean|), clamped for
/// near-zero means.
pub struct CoefficientOfVariationMeasure;

impl DatasetMeasure for CoefficientOfVariationMeasure {
    fn name(&self) -> &'static str {
        "cv"
    }

    fn of_subset(&self, frame: &Frame, _codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> f64 {
        if cols.is_empty() || rows.len() < 2 {
            return 0.0;
        }
        let mut total = 0.0;
        for &c in cols {
            let xs = subset_column(frame, c, rows);
            let m = stats::mean(&xs);
            let s = stats::std(&xs);
            total += s / m.abs().max(1e-9);
        }
        (total / cols.len() as f64).min(1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Column, Frame};

    fn frame() -> (Frame, CodeMatrix) {
        let f = Frame::new(
            "t",
            vec![
                Column::numeric("a", vec![3.0, -4.0, 0.0, 5.0]),
                Column::numeric("b", vec![1.0, 2.0, 3.0, 4.0]),
                Column::numeric("c", vec![2.0, 4.0, 6.0, 8.0]), // 2*b
                Column::categorical("y", vec![0.0, 1.0, 0.0, 1.0]),
            ],
            3,
        );
        let codes = CodeMatrix::from_frame(&f);
        (f, codes)
    }

    #[test]
    fn pnorm_hand_computed() {
        let (f, codes) = frame();
        let m = PNormMeasure { p: 2.0 };
        // col a rows all: sqrt((9+16+0+25)/4) = sqrt(12.5)
        let got = m.of_subset(&f, &codes, &[0, 1, 2, 3], &[0]);
        assert!((got - 12.5f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn pnorm_row_subset_differs() {
        let (f, codes) = frame();
        let m = PNormMeasure { p: 2.0 };
        let full = m.of_subset(&f, &codes, &[0, 1, 2, 3], &[0]);
        let sub = m.of_subset(&f, &codes, &[2], &[0]); // only the zero row
        assert!(sub < full);
    }

    #[test]
    fn correlation_detects_linear_dependence() {
        let (f, codes) = frame();
        let m = MeanCorrelationMeasure;
        // b and c are perfectly correlated
        let r = m.of_subset(&f, &codes, &[0, 1, 2, 3], &[1, 2]);
        assert!((r - 1.0).abs() < 1e-9);
        let degenerate = m.of_subset(&f, &codes, &[0, 1, 2, 3], &[1]);
        assert_eq!(degenerate, 0.0);
    }

    #[test]
    fn cv_zero_for_constant() {
        let f = Frame::new(
            "t",
            vec![
                Column::numeric("a", vec![5.0; 10]),
                Column::categorical("y", vec![0.0; 10]),
            ],
            1,
        );
        let codes = CodeMatrix::from_frame(&f);
        let m = CoefficientOfVariationMeasure;
        assert!(m.of_subset(&f, &codes, &(0..10).collect::<Vec<_>>(), &[0]) < 1e-9);
    }

    #[test]
    fn measures_are_subset_sensitive() {
        // each alternative measure must distinguish at least some subsets
        let (f, codes) = frame();
        let rows_a: Vec<u32> = vec![0, 1];
        let rows_b: Vec<u32> = vec![2, 3];
        for m in [
            &PNormMeasure { p: 2.0 } as &dyn DatasetMeasure,
            &CoefficientOfVariationMeasure,
        ] {
            let a = m.of_subset(&f, &codes, &rows_a, &[0, 1]);
            let b = m.of_subset(&f, &codes, &rows_b, &[0, 1]);
            assert!((a - b).abs() > 1e-9, "{} cannot discriminate", m.name());
        }
    }
}
