//! Native (pure-rust) interpreter for the AOT artifact *contracts* —
//! the offline substrate for PJRT (DESIGN.md §3.8).
//!
//! The `xla` crate and the compiled `artifacts/*.hlo.txt` modules are not
//! available in this environment, but every artifact has a small, fixed
//! numeric contract (documented in `python/compile/` and pinned by
//! `artifacts/manifest.txt` shapes). This module implements those
//! contracts directly on the padded buffers, so the whole XLA-backed
//! surface — `EntropyExec`, `ModelsExec`, the logreg/MLP model-zoo
//! members, the k-means baseline, and the `Xla` fitness backend — keeps
//! working on CPU-only testbeds. When the real PJRT path returns
//! (vendored `xla` crate + artifacts), this stays as the reference the
//! kernels are cross-checked against (integration tests compare the two
//! within f32 tolerance).
//!
//! Shapes are the pinned constants of [`crate::runtime::shapes`]; every
//! function takes the exact padded buffers its artifact was lowered for.

use crate::data::binning::K_BINS;
use crate::runtime::shapes::{
    BATCH, B_BATCH, C_PAD, EPOCH_TILES, F_PAD, HIDDEN, KM_DIM, KM_K, KM_POINTS, M_PAD, N_PAD,
};

/// Logit value of a masked-out class (matches the python-side padding
/// contract: padded logits get -1e9 so softmax/argmax never pick them).
const MASKED_LOGIT: f32 = -1e9;

/// Shannon entropy (bits) over one masked column of a padded code tile.
fn masked_column_entropy(codes: &[i32], rmask: &[f32], col: usize) -> f64 {
    let mut counts = [0u64; K_BINS];
    let mut n = 0u64;
    for (i, &m) in rmask.iter().enumerate() {
        if m > 0.0 {
            let code = (codes[i * M_PAD + col].max(0) as usize).min(K_BINS - 1);
            counts[code] += 1;
            n += 1;
        }
    }
    if n == 0 {
        return 0.0;
    }
    let n = n as f64;
    let mut h = 0.0;
    for &c in &counts {
        if c > 0 {
            let p = c as f64 / n;
            h -= p * p.log2();
        }
    }
    h
}

/// `entropy_subset`: mean masked-column entropy of one (N_PAD, M_PAD)
/// code tile. Output: one f32.
pub fn entropy_subset(codes: &[i32], rmask: &[f32], cmask: &[f32]) -> f32 {
    let mut sum = 0.0f64;
    let mut m = 0usize;
    for (j, &cm) in cmask.iter().enumerate().take(M_PAD) {
        if cm > 0.0 {
            sum += masked_column_entropy(codes, rmask, j);
            m += 1;
        }
    }
    if m == 0 {
        0.0
    } else {
        (sum / m as f64) as f32
    }
}

/// `entropy_columns`: per-column entropies of one tile (masked-out
/// columns are not distinguished — every slot is reduced; callers slice
/// the active prefix). Output: f32[M_PAD].
pub fn entropy_columns(codes: &[i32], rmask: &[f32]) -> Vec<f32> {
    (0..M_PAD)
        .map(|j| masked_column_entropy(codes, rmask, j) as f32)
        .collect()
}

/// `entropy_batch`: [`entropy_subset`] over B_BATCH stacked tiles.
/// Output: f32[B_BATCH].
pub fn entropy_batch(codes: &[i32], rmask: &[f32], cmask: &[f32]) -> Vec<f32> {
    (0..B_BATCH)
        .map(|b| {
            entropy_subset(
                &codes[b * N_PAD * M_PAD..(b + 1) * N_PAD * M_PAD],
                &rmask[b * N_PAD..(b + 1) * N_PAD],
                &cmask[b * M_PAD..(b + 1) * M_PAD],
            )
        })
        .collect()
}

/// Masked linear logits for one padded batch row-block:
/// `out[i, c] = x[i] . w[:, c] + b[c]` for active classes, else -1e9.
fn linear_logits(x: &[f32], w: &[f32], b: &[f32], cmask: &[f32], in_dim: usize) -> Vec<f32> {
    let rows = x.len() / in_dim;
    let mut out = vec![0f32; rows * C_PAD];
    for i in 0..rows {
        let xr = &x[i * in_dim..(i + 1) * in_dim];
        let logits = &mut out[i * C_PAD..(i + 1) * C_PAD];
        logits.copy_from_slice(&b[..C_PAD]);
        for (f, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue; // padded features are zero — skip the fan-out
            }
            let wr = &w[f * C_PAD..(f + 1) * C_PAD];
            for c in 0..C_PAD {
                logits[c] += xv * wr[c];
            }
        }
        for c in 0..C_PAD {
            if cmask[c] <= 0.0 {
                logits[c] = MASKED_LOGIT;
            }
        }
    }
    out
}

/// Stable softmax of one logit row (masked slots come in at -1e9 and
/// round to probability 0).
fn softmax_row(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().copied().fold(f32::MIN, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z.max(1e-30)).collect()
}

/// `logreg_predict`: masked logits, (BATCH, C_PAD) row-major.
pub fn logreg_predict(x: &[f32], w: &[f32], b: &[f32], cmask: &[f32]) -> Vec<f32> {
    linear_logits(x, w, b, cmask, F_PAD)
}

/// `logreg_train_step`: one masked mini-batch SGD step of softmax
/// regression with L2; updates (w, b) in place and returns the mean
/// cross-entropy over active samples (0.0 for an all-masked batch, which
/// is a no-op step — the epoch scan relies on that).
#[allow(clippy::too_many_arguments)]
pub fn logreg_step(
    x: &[f32],
    yoh: &[f32],
    smask: &[f32],
    cmask: &[f32],
    w: &mut [f32],
    b: &mut [f32],
    lr: f32,
    l2: f32,
) -> f32 {
    let active: f32 = smask.iter().sum();
    if active <= 0.0 {
        return 0.0;
    }
    let logits = linear_logits(x, w, b, cmask, F_PAD);
    let mut gw = vec![0f32; F_PAD * C_PAD];
    let mut gb = vec![0f32; C_PAD];
    let mut loss = 0f64;
    for i in 0..BATCH {
        if smask[i] <= 0.0 {
            continue;
        }
        let p = softmax_row(&logits[i * C_PAD..(i + 1) * C_PAD]);
        let yr = &yoh[i * C_PAD..(i + 1) * C_PAD];
        for c in 0..C_PAD {
            if yr[c] > 0.0 {
                loss -= (p[c].max(1e-12) as f64).ln();
            }
        }
        let xr = &x[i * F_PAD..(i + 1) * F_PAD];
        for c in 0..C_PAD {
            let d = (p[c] - yr[c]) * cmask[c] / active;
            if d == 0.0 {
                continue;
            }
            gb[c] += d;
            for (f, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    gw[f * C_PAD + c] += d * xv;
                }
            }
        }
    }
    for (wv, &g) in w.iter_mut().zip(&gw) {
        *wv -= lr * (g + l2 * *wv);
    }
    for (bv, &g) in b.iter_mut().zip(&gb) {
        *bv -= lr * g;
    }
    (loss / active as f64) as f32
}

/// `logreg_train_epoch`: EPOCH_TILES sequential [`logreg_step`]s over a
/// stacked tile batch; returns the last active tile's loss.
#[allow(clippy::too_many_arguments)]
pub fn logreg_epoch(
    x: &[f32],
    yoh: &[f32],
    smask: &[f32],
    cmask: &[f32],
    w: &mut [f32],
    b: &mut [f32],
    lr: f32,
    l2: f32,
) -> f32 {
    let mut loss = 0f32;
    for t in 0..EPOCH_TILES {
        let sm = &smask[t * BATCH..(t + 1) * BATCH];
        if sm.iter().all(|&m| m <= 0.0) {
            continue; // padded tile: exact no-op
        }
        loss = logreg_step(
            &x[t * BATCH * F_PAD..(t + 1) * BATCH * F_PAD],
            &yoh[t * BATCH * C_PAD..(t + 1) * BATCH * C_PAD],
            sm,
            cmask,
            w,
            b,
            lr,
            l2,
        );
    }
    loss
}

/// MLP forward pass for one padded batch: returns (hidden activations
/// tanh(x@w1+b1) as (rows, HIDDEN), masked logits as (rows, C_PAD)).
fn mlp_forward(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    cmask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / F_PAD;
    let mut h = vec![0f32; rows * HIDDEN];
    for i in 0..rows {
        let xr = &x[i * F_PAD..(i + 1) * F_PAD];
        let hr = &mut h[i * HIDDEN..(i + 1) * HIDDEN];
        hr.copy_from_slice(&b1[..HIDDEN]);
        for (f, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w1[f * HIDDEN..(f + 1) * HIDDEN];
            for j in 0..HIDDEN {
                hr[j] += xv * wr[j];
            }
        }
        for v in hr.iter_mut() {
            *v = v.tanh();
        }
    }
    let logits = linear_logits(&h, w2, b2, cmask, HIDDEN);
    (h, logits)
}

/// `mlp_predict`: masked logits of the one-hidden-layer tanh MLP.
pub fn mlp_predict(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    cmask: &[f32],
) -> Vec<f32> {
    mlp_forward(x, w1, b1, w2, b2, cmask).1
}

/// `mlp_train_step`: one masked mini-batch SGD step of the MLP
/// (softmax cross-entropy, tanh hidden layer, L2 on both weight
/// matrices); updates parameters in place and returns the mean loss.
#[allow(clippy::too_many_arguments)]
pub fn mlp_step(
    x: &[f32],
    yoh: &[f32],
    smask: &[f32],
    cmask: &[f32],
    w1: &mut [f32],
    b1: &mut [f32],
    w2: &mut [f32],
    b2: &mut [f32],
    lr: f32,
    l2: f32,
) -> f32 {
    let active: f32 = smask.iter().sum();
    if active <= 0.0 {
        return 0.0;
    }
    let (h, logits) = mlp_forward(x, w1, b1, w2, b2, cmask);
    let mut gw1 = vec![0f32; F_PAD * HIDDEN];
    let mut gb1 = vec![0f32; HIDDEN];
    let mut gw2 = vec![0f32; HIDDEN * C_PAD];
    let mut gb2 = vec![0f32; C_PAD];
    let mut loss = 0f64;
    for i in 0..BATCH {
        if smask[i] <= 0.0 {
            continue;
        }
        let p = softmax_row(&logits[i * C_PAD..(i + 1) * C_PAD]);
        let yr = &yoh[i * C_PAD..(i + 1) * C_PAD];
        let hr = &h[i * HIDDEN..(i + 1) * HIDDEN];
        let xr = &x[i * F_PAD..(i + 1) * F_PAD];
        let mut dlogit = [0f32; C_PAD];
        for c in 0..C_PAD {
            if yr[c] > 0.0 {
                loss -= (p[c].max(1e-12) as f64).ln();
            }
            dlogit[c] = (p[c] - yr[c]) * cmask[c] / active;
        }
        // output layer grads + backprop into the hidden activations
        let mut dh = [0f32; HIDDEN];
        for c in 0..C_PAD {
            let d = dlogit[c];
            if d == 0.0 {
                continue;
            }
            gb2[c] += d;
            for j in 0..HIDDEN {
                gw2[j * C_PAD + c] += d * hr[j];
                dh[j] += d * w2[j * C_PAD + c];
            }
        }
        // through tanh: dpre = dh * (1 - h^2)
        for (j, dv) in dh.iter_mut().enumerate() {
            *dv *= 1.0 - hr[j] * hr[j];
            gb1[j] += *dv;
        }
        for (f, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let gr = &mut gw1[f * HIDDEN..(f + 1) * HIDDEN];
            for j in 0..HIDDEN {
                gr[j] += dh[j] * xv;
            }
        }
    }
    for (wv, &g) in w1.iter_mut().zip(&gw1) {
        *wv -= lr * (g + l2 * *wv);
    }
    for (bv, &g) in b1.iter_mut().zip(&gb1) {
        *bv -= lr * g;
    }
    for (wv, &g) in w2.iter_mut().zip(&gw2) {
        *wv -= lr * (g + l2 * *wv);
    }
    for (bv, &g) in b2.iter_mut().zip(&gb2) {
        *bv -= lr * g;
    }
    (loss / active as f64) as f32
}

/// `mlp_train_epoch`: EPOCH_TILES sequential [`mlp_step`]s.
#[allow(clippy::too_many_arguments)]
pub fn mlp_epoch(
    x: &[f32],
    yoh: &[f32],
    smask: &[f32],
    cmask: &[f32],
    w1: &mut [f32],
    b1: &mut [f32],
    w2: &mut [f32],
    b2: &mut [f32],
    lr: f32,
    l2: f32,
) -> f32 {
    let mut loss = 0f32;
    for t in 0..EPOCH_TILES {
        let sm = &smask[t * BATCH..(t + 1) * BATCH];
        if sm.iter().all(|&m| m <= 0.0) {
            continue;
        }
        loss = mlp_step(
            &x[t * BATCH * F_PAD..(t + 1) * BATCH * F_PAD],
            &yoh[t * BATCH * C_PAD..(t + 1) * BATCH * C_PAD],
            sm,
            cmask,
            w1,
            b1,
            w2,
            b2,
            lr,
            l2,
        );
    }
    loss
}

/// `kmeans_step`: one Lloyd iteration over a padded point tile. Returns
/// (updated centroids, per-point nearest-centroid assignment). Inactive
/// points (pmask 0) get assignment 0 and never pull centroids; centroids
/// with no members keep their input position.
pub fn kmeans_step(points: &[f32], pmask: &[f32], centroids: &[f32]) -> (Vec<f32>, Vec<i32>) {
    let mut assign = vec![0i32; KM_POINTS];
    let mut sums = vec![0f64; KM_K * KM_DIM];
    let mut counts = vec![0u64; KM_K];
    for i in 0..KM_POINTS {
        if pmask[i] <= 0.0 {
            continue;
        }
        let pr = &points[i * KM_DIM..(i + 1) * KM_DIM];
        let mut best = 0usize;
        let mut best_d = f32::MAX;
        for c in 0..KM_K {
            let cr = &centroids[c * KM_DIM..(c + 1) * KM_DIM];
            let mut d = 0f32;
            for j in 0..KM_DIM {
                let diff = pr[j] - cr[j];
                d += diff * diff;
            }
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        assign[i] = best as i32;
        counts[best] += 1;
        for j in 0..KM_DIM {
            sums[best * KM_DIM + j] += pr[j] as f64;
        }
    }
    let mut out = centroids.to_vec();
    for c in 0..KM_K {
        if counts[c] > 0 {
            for j in 0..KM_DIM {
                out[c * KM_DIM + j] = (sums[c * KM_DIM + j] / counts[c] as f64) as f32;
            }
        }
    }
    (out, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CodeMatrix;
    use crate::data::{Column, Frame};
    use crate::measures::entropy::subset_entropy as native_subset_entropy;
    use crate::util::rng::Rng;

    fn toy_codes() -> (Frame, CodeMatrix) {
        let mut rng = Rng::new(3);
        let n = 120;
        let cols = vec![
            Column::numeric("a", (0..n).map(|_| rng.f32()).collect()),
            Column::categorical("c", (0..n).map(|_| rng.usize_below(5) as f32).collect()),
            Column::categorical("y", (0..n).map(|_| rng.usize_below(3) as f32).collect()),
        ];
        let f = Frame::new("toy", cols, 2);
        let codes = CodeMatrix::from_frame(&f);
        (f, codes)
    }

    /// Pack a subset into the (N_PAD, M_PAD) tile the artifact expects.
    fn pack(codes: &CodeMatrix, rows: &[u32], cols: &[u32]) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
        let mut tile = vec![0i32; N_PAD * M_PAD];
        let mut rmask = vec![0f32; N_PAD];
        let mut cmask = vec![0f32; M_PAD];
        for (j, &c) in cols.iter().enumerate() {
            let col = codes.column(c as usize);
            for (i, &r) in rows.iter().enumerate() {
                tile[i * M_PAD + j] = col[r as usize] as i32;
            }
        }
        rmask[..rows.len()].fill(1.0);
        cmask[..cols.len()].fill(1.0);
        (tile, rmask, cmask)
    }

    #[test]
    fn entropy_contract_matches_measures_substrate() {
        let (f, codes) = toy_codes();
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let rows = rng.sample_distinct(f.n_rows, 1 + rng.usize_below(100));
            let cols = rng.sample_distinct(f.n_cols(), 1 + rng.usize_below(3));
            let (tile, rmask, cmask) = pack(&codes, &rows, &cols);
            let got = entropy_subset(&tile, &rmask, &cmask) as f64;
            let want = native_subset_entropy(&codes, &rows, &cols);
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn softmax_handles_masked_logits() {
        let mut logits = vec![MASKED_LOGIT; C_PAD];
        logits[0] = 1.0;
        logits[1] = 1.0;
        let p = softmax_row(&logits);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p[2..].iter().all(|&v| v < 1e-12));
    }

    #[test]
    fn logreg_step_reduces_loss_on_separable_batch() {
        let mut rng = Rng::new(4);
        let mut x = vec![0f32; BATCH * F_PAD];
        let mut yoh = vec![0f32; BATCH * C_PAD];
        let smask = vec![1f32; BATCH];
        for i in 0..BATCH {
            let c = i % 2;
            yoh[i * C_PAD + c] = 1.0;
            for f in 0..4 {
                x[i * F_PAD + f] = (c as f64 * 4.0 - 2.0 + rng.normal()) as f32;
            }
        }
        let cmask = {
            let mut m = vec![0f32; C_PAD];
            m[0] = 1.0;
            m[1] = 1.0;
            m
        };
        let mut w = vec![0f32; F_PAD * C_PAD];
        let mut b = vec![0f32; C_PAD];
        let first = logreg_step(&x, &yoh, &smask, &cmask, &mut w, &mut b, 0.5, 0.0);
        let mut last = first;
        for _ in 0..15 {
            last = logreg_step(&x, &yoh, &smask, &cmask, &mut w, &mut b, 0.5, 0.0);
        }
        assert!(last < first * 0.5, "loss not decreasing: {first} -> {last}");
    }

    #[test]
    fn zero_mask_step_is_noop() {
        let x = vec![1f32; BATCH * F_PAD];
        let yoh = vec![0f32; BATCH * C_PAD];
        let smask = vec![0f32; BATCH];
        let cmask = vec![1f32; C_PAD];
        let mut w = vec![0.5f32; F_PAD * C_PAD];
        let mut b = vec![0.25f32; C_PAD];
        let (w0, b0) = (w.clone(), b.clone());
        let loss = logreg_step(&x, &yoh, &smask, &cmask, &mut w, &mut b, 0.5, 0.1);
        assert_eq!(loss, 0.0);
        assert_eq!(w, w0);
        assert_eq!(b, b0);
    }

    #[test]
    fn kmeans_assigns_to_nearest_and_averages() {
        let mut points = vec![0f32; KM_POINTS * KM_DIM];
        let mut pmask = vec![0f32; KM_POINTS];
        // two clusters on the first coordinate at -4 and +4
        for i in 0..200 {
            points[i * KM_DIM] = if i < 100 { -4.0 } else { 4.0 };
            pmask[i] = 1.0;
        }
        let mut centroids = vec![1e6f32; KM_K * KM_DIM];
        centroids[0] = -1.0;
        centroids[KM_DIM] = 1.0;
        // zero the non-first coords of the two active centroid slots
        for j in 1..KM_DIM {
            centroids[j] = 0.0;
            centroids[KM_DIM + j] = 0.0;
        }
        let (new_c, assign) = kmeans_step(&points, &pmask, &centroids);
        assert!(assign[..100].iter().all(|&a| a == 0));
        assert!(assign[100..200].iter().all(|&a| a == 1));
        assert!((new_c[0] + 4.0).abs() < 1e-5);
        assert!((new_c[KM_DIM] - 4.0).abs() < 1e-5);
        // untouched slot keeps its position
        assert_eq!(new_c[2 * KM_DIM], 1e6);
    }
}
