//! Typed wrapper over the entropy artifacts: packs a data subset
//! (code matrix + row/col index sets) into the fixed (N_PAD, M_PAD) tile
//! with masks, executes on PJRT, and returns H(d).
//!
//! This is the XLA fitness backend for Gen-DST (`gendst::fitness`
//! chooses between this and the native path; see DESIGN.md §7 for the
//! CPU-vs-TPU trade-off).

use crate::ensure;
use crate::util::error::Result;

use crate::data::CodeMatrix;
use crate::runtime::shapes::{B_BATCH, M_PAD, N_PAD};
use crate::runtime::{arg_f32, arg_i32, to_vec_f32, XlaRuntime};

/// Reusable packing buffers (avoid per-call allocation in the GA loop).
pub struct EntropyExec<'rt> {
    rt: &'rt XlaRuntime,
    codes_buf: Vec<i32>,
    rmask_buf: Vec<f32>,
    cmask_buf: Vec<f32>,
}

impl<'rt> EntropyExec<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> EntropyExec<'rt> {
        EntropyExec {
            rt,
            codes_buf: vec![0; N_PAD * M_PAD],
            rmask_buf: vec![0.0; N_PAD],
            cmask_buf: vec![0.0; M_PAD],
        }
    }

    fn pack_into(
        codes: &CodeMatrix,
        rows: &[u32],
        cols: &[u32],
        codes_buf: &mut [i32],
        rmask_buf: &mut [f32],
        cmask_buf: &mut [f32],
    ) -> Result<()> {
        ensure!(rows.len() <= N_PAD, "subset rows {} > N_PAD {N_PAD}", rows.len());
        ensure!(cols.len() <= M_PAD, "subset cols {} > M_PAD {M_PAD}", cols.len());
        codes_buf.fill(0);
        rmask_buf.fill(0.0);
        cmask_buf.fill(0.0);
        for (j, &c) in cols.iter().enumerate() {
            let col = codes.column(c as usize);
            for (i, &r) in rows.iter().enumerate() {
                // row-major (N_PAD, M_PAD) tile
                codes_buf[i * M_PAD + j] = col[r as usize] as i32;
            }
        }
        rmask_buf[..rows.len()].fill(1.0);
        cmask_buf[..cols.len()].fill(1.0);
        Ok(())
    }

    /// H(D[rows, cols]) through the `entropy_subset` artifact.
    pub fn subset_entropy(
        &mut self,
        codes: &CodeMatrix,
        rows: &[u32],
        cols: &[u32],
    ) -> Result<f64> {
        Self::pack_into(
            codes,
            rows,
            cols,
            &mut self.codes_buf,
            &mut self.rmask_buf,
            &mut self.cmask_buf,
        )?;
        let out = self.rt.execute(
            "entropy_subset",
            &[
                arg_i32(&self.codes_buf, &[N_PAD as i64, M_PAD as i64])?,
                arg_f32(&self.rmask_buf, &[N_PAD as i64])?,
                arg_f32(&self.cmask_buf, &[M_PAD as i64])?,
            ],
        )?;
        Ok(to_vec_f32(&out[0])?[0] as f64)
    }

    /// Batched fitness: entropies for up to B_BATCH subsets in one call.
    /// Returns one H per (rows, cols) pair, in order.
    pub fn batch_entropy(
        &mut self,
        codes: &CodeMatrix,
        subsets: &[(&[u32], &[u32])],
    ) -> Result<Vec<f64>> {
        ensure!(!subsets.is_empty(), "empty batch");
        let mut out = Vec::with_capacity(subsets.len());
        for chunk in subsets.chunks(B_BATCH) {
            let mut codes_b = vec![0i32; B_BATCH * N_PAD * M_PAD];
            let mut rmask_b = vec![0.0f32; B_BATCH * N_PAD];
            let mut cmask_b = vec![0.0f32; B_BATCH * M_PAD];
            for (b, (rows, cols)) in chunk.iter().enumerate() {
                Self::pack_into(
                    codes,
                    rows,
                    cols,
                    &mut codes_b[b * N_PAD * M_PAD..(b + 1) * N_PAD * M_PAD],
                    &mut rmask_b[b * N_PAD..(b + 1) * N_PAD],
                    &mut cmask_b[b * M_PAD..(b + 1) * M_PAD],
                )?;
            }
            // padded batch slots keep zero masks -> defined H=0, ignored
            for b in chunk.len()..B_BATCH {
                rmask_b[b * N_PAD] = 1.0;
                cmask_b[b * M_PAD] = 1.0;
            }
            let res = self.rt.execute(
                "entropy_batch",
                &[
                    arg_i32(&codes_b, &[B_BATCH as i64, N_PAD as i64, M_PAD as i64])?,
                    arg_f32(&rmask_b, &[B_BATCH as i64, N_PAD as i64])?,
                    arg_f32(&cmask_b, &[B_BATCH as i64, M_PAD as i64])?,
                ],
            )?;
            let h = to_vec_f32(&res[0])?;
            out.extend(h[..chunk.len()].iter().map(|&x| x as f64));
        }
        Ok(out)
    }

    /// Per-column entropies of up to N_PAD sampled rows (profile of D).
    pub fn column_entropies(
        &mut self,
        codes: &CodeMatrix,
        rows: &[u32],
        cols: &[u32],
    ) -> Result<Vec<f64>> {
        Self::pack_into(
            codes,
            rows,
            cols,
            &mut self.codes_buf,
            &mut self.rmask_buf,
            &mut self.cmask_buf,
        )?;
        let out = self.rt.execute(
            "entropy_columns",
            &[
                arg_i32(&self.codes_buf, &[N_PAD as i64, M_PAD as i64])?,
                arg_f32(&self.rmask_buf, &[N_PAD as i64])?,
            ],
        )?;
        let h = to_vec_f32(&out[0])?;
        Ok(h[..cols.len()].iter().map(|&x| x as f64).collect())
    }
}
