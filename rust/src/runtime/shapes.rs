//! Mirror of `python/compile/shapes.py` — the fixed padded shapes every
//! AOT artifact was compiled for. KEEP IN SYNC with the python side; the
//! integration test `artifact_shapes_match_manifest` cross-checks these
//! constants against `artifacts/manifest.txt` at test time.

/// max subset rows per entropy tile (sqrt(1M) rounded up to a tile)
pub const N_PAD: usize = 1024;
/// max subset columns per entropy tile (0.25 * 123 rounded up)
pub const M_PAD: usize = 32;
/// per-column value codes (quantile binning at ingest)
pub const K_BINS: usize = 64;
/// GA candidates per batched entropy call
pub const B_BATCH: usize = 16;

/// feature dim after padding (widest dataset: 123 columns)
pub const F_PAD: usize = 128;
/// class dim after padding (max classes in Table 2: 10)
pub const C_PAD: usize = 16;
/// training mini-batch rows
pub const BATCH: usize = 256;
/// MLP hidden width
pub const HIDDEN: usize = 64;
/// mini-batches scanned inside one train_epoch call (one PJRT call
/// trains EPOCH_TILES*BATCH = 4096 rows — see §Perf)
pub const EPOCH_TILES: usize = 16;

/// k-means tile: points per call / point dim / max centroids
pub const KM_POINTS: usize = 1024;
pub const KM_DIM: usize = 32;
pub const KM_K: usize = 32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_bins_matches_binning_substrate() {
        assert_eq!(K_BINS, crate::data::binning::K_BINS);
    }

    #[test]
    fn entropy_tile_covers_every_table2_dataset() {
        for info in crate::data::registry::table2() {
            let n = (info.n_rows as f64).sqrt().ceil() as usize;
            let m = (0.25 * (info.n_cols as f64)).ceil() as usize;
            assert!(n <= N_PAD, "{}: sqrt(N)={n} > N_PAD", info.symbol);
            assert!(m <= M_PAD, "{}: 0.25M={m} > M_PAD", info.symbol);
            assert!(info.n_cols - 1 <= F_PAD, "{} features", info.symbol);
            assert!(info.n_classes <= C_PAD, "{} classes", info.symbol);
        }
    }
}
