//! Typed wrappers over the model-training artifacts: logistic regression
//! and MLP train/predict steps, and the k-means Lloyd step. These are the
//! L2 graphs the model zoo's XLA-backed members call per mini-batch.
//!
//! Padding contract (DESIGN.md §6): features zero-padded to F_PAD,
//! classes to C_PAD with a {0,1} class mask (padded logits get -1e9),
//! rows to BATCH with a {0,1} sample mask.

use crate::ensure;
use crate::util::error::Result;

use crate::data::Matrix;
use crate::runtime::shapes::{
    BATCH, C_PAD, EPOCH_TILES, F_PAD, HIDDEN, KM_DIM, KM_K, KM_POINTS,
};
use crate::runtime::{arg_f32, to_vec_f32, to_vec_i32, XlaRuntime};

/// Logistic-regression parameters (padded shapes).
#[derive(Debug, Clone)]
pub struct LogregParams {
    pub w: Vec<f32>, // (F_PAD, C_PAD) row-major
    pub b: Vec<f32>, // (C_PAD,)
}

impl LogregParams {
    pub fn zeros() -> LogregParams {
        LogregParams {
            w: vec![0.0; F_PAD * C_PAD],
            b: vec![0.0; C_PAD],
        }
    }
}

/// MLP parameters (padded shapes).
#[derive(Debug, Clone)]
pub struct MlpParams {
    pub w1: Vec<f32>, // (F_PAD, HIDDEN)
    pub b1: Vec<f32>, // (HIDDEN,)
    pub w2: Vec<f32>, // (HIDDEN, C_PAD)
    pub b2: Vec<f32>, // (C_PAD,)
}

impl MlpParams {
    /// Small random init (He-ish scale for tanh).
    pub fn init(rng: &mut crate::util::rng::Rng) -> MlpParams {
        let s1 = (1.0 / F_PAD as f64).sqrt();
        let s2 = (1.0 / HIDDEN as f64).sqrt();
        MlpParams {
            w1: (0..F_PAD * HIDDEN)
                .map(|_| (rng.normal() * s1) as f32)
                .collect(),
            b1: vec![0.0; HIDDEN],
            w2: (0..HIDDEN * C_PAD)
                .map(|_| (rng.normal() * s2) as f32)
                .collect(),
            b2: vec![0.0; C_PAD],
        }
    }
}

/// One padded training batch: features, one-hot labels, masks.
pub struct PackedBatch {
    pub x: Vec<f32>,     // (BATCH, F_PAD)
    pub yoh: Vec<f32>,   // (BATCH, C_PAD)
    pub smask: Vec<f32>, // (BATCH,)
}

/// Pack rows `idx` of (x, y) into a padded batch. `n_cols <= F_PAD`.
pub fn pack_batch(x: &Matrix, y: &[u32], idx: &[usize]) -> Result<PackedBatch> {
    ensure!(x.cols <= F_PAD, "features {} > F_PAD {F_PAD}", x.cols);
    ensure!(idx.len() <= BATCH, "batch {} > BATCH {BATCH}", idx.len());
    let mut xb = vec![0.0f32; BATCH * F_PAD];
    let mut yoh = vec![0.0f32; BATCH * C_PAD];
    let mut smask = vec![0.0f32; BATCH];
    for (i, &r) in idx.iter().enumerate() {
        xb[i * F_PAD..i * F_PAD + x.cols].copy_from_slice(x.row(r));
        let cls = (y[r] as usize).min(C_PAD - 1);
        yoh[i * C_PAD + cls] = 1.0;
        smask[i] = 1.0;
    }
    Ok(PackedBatch { x: xb, yoh, smask })
}

/// Class mask with the first `n_classes` slots active.
pub fn class_mask(n_classes: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; C_PAD];
    m[..n_classes.min(C_PAD)].fill(1.0);
    m
}

/// A padded epoch tile-stack: EPOCH_TILES consecutive mini-batches fed to
/// one `*_train_epoch` call. Unused tiles keep zero sample masks (no-op
/// steps inside the scan).
pub struct PackedEpoch {
    pub x: Vec<f32>,     // (EPOCH_TILES, BATCH, F_PAD)
    pub yoh: Vec<f32>,   // (EPOCH_TILES, BATCH, C_PAD)
    pub smask: Vec<f32>, // (EPOCH_TILES, BATCH)
}

/// Pack up to EPOCH_TILES*BATCH row indices into one epoch stack.
pub fn pack_epoch(x: &Matrix, y: &[u32], idx: &[usize]) -> Result<PackedEpoch> {
    ensure!(x.cols <= F_PAD, "features {} > F_PAD {F_PAD}", x.cols);
    ensure!(
        idx.len() <= EPOCH_TILES * BATCH,
        "epoch chunk {} > {}",
        idx.len(),
        EPOCH_TILES * BATCH
    );
    let mut xb = vec![0.0f32; EPOCH_TILES * BATCH * F_PAD];
    let mut yoh = vec![0.0f32; EPOCH_TILES * BATCH * C_PAD];
    let mut smask = vec![0.0f32; EPOCH_TILES * BATCH];
    for (i, &r) in idx.iter().enumerate() {
        xb[i * F_PAD..i * F_PAD + x.cols].copy_from_slice(x.row(r));
        let cls = (y[r] as usize).min(C_PAD - 1);
        yoh[i * C_PAD + cls] = 1.0;
        smask[i] = 1.0;
    }
    Ok(PackedEpoch { x: xb, yoh, smask })
}

pub struct ModelsExec<'rt> {
    rt: &'rt XlaRuntime,
}

impl<'rt> ModelsExec<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> ModelsExec<'rt> {
        ModelsExec { rt }
    }

    /// One SGD step; returns the loss. Parameters are updated in place.
    pub fn logreg_step(
        &self,
        params: &mut LogregParams,
        batch: &PackedBatch,
        cmask: &[f32],
        lr: f32,
        l2: f32,
    ) -> Result<f32> {
        let out = self.rt.execute(
            "logreg_train_step",
            &[
                arg_f32(&batch.x, &[BATCH as i64, F_PAD as i64])?,
                arg_f32(&batch.yoh, &[BATCH as i64, C_PAD as i64])?,
                arg_f32(&batch.smask, &[BATCH as i64])?,
                arg_f32(cmask, &[C_PAD as i64])?,
                arg_f32(&params.w, &[F_PAD as i64, C_PAD as i64])?,
                arg_f32(&params.b, &[C_PAD as i64])?,
                arg_f32(&[lr], &[])?,
                arg_f32(&[l2], &[])?,
            ],
        )?;
        params.w = to_vec_f32(&out[0])?;
        params.b = to_vec_f32(&out[1])?;
        Ok(to_vec_f32(&out[2])?[0])
    }

    /// EPOCH_TILES SGD steps in one PJRT call (see `pack_epoch`).
    pub fn logreg_epoch(
        &self,
        params: &mut LogregParams,
        epoch: &PackedEpoch,
        cmask: &[f32],
        lr: f32,
        l2: f32,
    ) -> Result<f32> {
        let (t, b) = (EPOCH_TILES as i64, BATCH as i64);
        let out = self.rt.execute(
            "logreg_train_epoch",
            &[
                arg_f32(&epoch.x, &[t, b, F_PAD as i64])?,
                arg_f32(&epoch.yoh, &[t, b, C_PAD as i64])?,
                arg_f32(&epoch.smask, &[t, b])?,
                arg_f32(cmask, &[C_PAD as i64])?,
                arg_f32(&params.w, &[F_PAD as i64, C_PAD as i64])?,
                arg_f32(&params.b, &[C_PAD as i64])?,
                arg_f32(&[lr], &[])?,
                arg_f32(&[l2], &[])?,
            ],
        )?;
        params.w = to_vec_f32(&out[0])?;
        params.b = to_vec_f32(&out[1])?;
        Ok(to_vec_f32(&out[2])?[0])
    }

    /// Masked logits for a padded batch: (BATCH, C_PAD) row-major.
    pub fn logreg_predict(
        &self,
        params: &LogregParams,
        batch_x: &[f32],
        cmask: &[f32],
    ) -> Result<Vec<f32>> {
        let out = self.rt.execute(
            "logreg_predict",
            &[
                arg_f32(batch_x, &[BATCH as i64, F_PAD as i64])?,
                arg_f32(&params.w, &[F_PAD as i64, C_PAD as i64])?,
                arg_f32(&params.b, &[C_PAD as i64])?,
                arg_f32(cmask, &[C_PAD as i64])?,
            ],
        )?;
        to_vec_f32(&out[0])
    }

    /// One SGD step for the MLP; returns the loss.
    pub fn mlp_step(
        &self,
        params: &mut MlpParams,
        batch: &PackedBatch,
        cmask: &[f32],
        lr: f32,
        l2: f32,
    ) -> Result<f32> {
        let out = self.rt.execute(
            "mlp_train_step",
            &[
                arg_f32(&batch.x, &[BATCH as i64, F_PAD as i64])?,
                arg_f32(&batch.yoh, &[BATCH as i64, C_PAD as i64])?,
                arg_f32(&batch.smask, &[BATCH as i64])?,
                arg_f32(cmask, &[C_PAD as i64])?,
                arg_f32(&params.w1, &[F_PAD as i64, HIDDEN as i64])?,
                arg_f32(&params.b1, &[HIDDEN as i64])?,
                arg_f32(&params.w2, &[HIDDEN as i64, C_PAD as i64])?,
                arg_f32(&params.b2, &[C_PAD as i64])?,
                arg_f32(&[lr], &[])?,
                arg_f32(&[l2], &[])?,
            ],
        )?;
        params.w1 = to_vec_f32(&out[0])?;
        params.b1 = to_vec_f32(&out[1])?;
        params.w2 = to_vec_f32(&out[2])?;
        params.b2 = to_vec_f32(&out[3])?;
        Ok(to_vec_f32(&out[4])?[0])
    }

    /// MLP twin of `logreg_epoch`.
    pub fn mlp_epoch(
        &self,
        params: &mut MlpParams,
        epoch: &PackedEpoch,
        cmask: &[f32],
        lr: f32,
        l2: f32,
    ) -> Result<f32> {
        let (t, b) = (EPOCH_TILES as i64, BATCH as i64);
        let out = self.rt.execute(
            "mlp_train_epoch",
            &[
                arg_f32(&epoch.x, &[t, b, F_PAD as i64])?,
                arg_f32(&epoch.yoh, &[t, b, C_PAD as i64])?,
                arg_f32(&epoch.smask, &[t, b])?,
                arg_f32(cmask, &[C_PAD as i64])?,
                arg_f32(&params.w1, &[F_PAD as i64, HIDDEN as i64])?,
                arg_f32(&params.b1, &[HIDDEN as i64])?,
                arg_f32(&params.w2, &[HIDDEN as i64, C_PAD as i64])?,
                arg_f32(&params.b2, &[C_PAD as i64])?,
                arg_f32(&[lr], &[])?,
                arg_f32(&[l2], &[])?,
            ],
        )?;
        params.w1 = to_vec_f32(&out[0])?;
        params.b1 = to_vec_f32(&out[1])?;
        params.w2 = to_vec_f32(&out[2])?;
        params.b2 = to_vec_f32(&out[3])?;
        Ok(to_vec_f32(&out[4])?[0])
    }

    /// Masked MLP logits: (BATCH, C_PAD) row-major.
    pub fn mlp_predict(
        &self,
        params: &MlpParams,
        batch_x: &[f32],
        cmask: &[f32],
    ) -> Result<Vec<f32>> {
        let out = self.rt.execute(
            "mlp_predict",
            &[
                arg_f32(batch_x, &[BATCH as i64, F_PAD as i64])?,
                arg_f32(&params.w1, &[F_PAD as i64, HIDDEN as i64])?,
                arg_f32(&params.b1, &[HIDDEN as i64])?,
                arg_f32(&params.w2, &[HIDDEN as i64, C_PAD as i64])?,
                arg_f32(&params.b2, &[C_PAD as i64])?,
                arg_f32(cmask, &[C_PAD as i64])?,
            ],
        )?;
        to_vec_f32(&out[0])
    }

    /// One Lloyd iteration on a padded point tile. Returns (new_centroids,
    /// assignments). Inactive points (pmask=0) never pull centroids.
    pub fn kmeans_step(
        &self,
        points: &[f32],    // (KM_POINTS, KM_DIM)
        pmask: &[f32],     // (KM_POINTS,)
        centroids: &[f32], // (KM_K, KM_DIM)
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let out = self.rt.execute(
            "kmeans_step",
            &[
                arg_f32(points, &[KM_POINTS as i64, KM_DIM as i64])?,
                arg_f32(pmask, &[KM_POINTS as i64])?,
                arg_f32(centroids, &[KM_K as i64, KM_DIM as i64])?,
            ],
        )?;
        Ok((to_vec_f32(&out[0])?, to_vec_i32(&out[1])?))
    }
}
