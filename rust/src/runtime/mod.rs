//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compile once per process-thread, execute from
//! the rust hot path. Python never runs here.
//!
//! Threading: the `xla` crate's `PjRtClient` wraps an `Rc`, so a runtime
//! instance is thread-confined. Worker threads that need XLA each create
//! (or lazily clone-compile) their own `XlaRuntime` via `thread_current()`;
//! compiled executables are cached per thread. For our workloads the
//! compile cost (~tens of ms per small module) amortizes over thousands
//! of `execute` calls.

pub mod entropy_exec;
pub mod models_exec;
pub mod shapes;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

/// A thread-confined PJRT CPU runtime with an executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Create a runtime reading artifacts from `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime {
            client,
            dir: dir.as_ref().to_path_buf(),
            exes: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifact directory: `$SUBSTRAT_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root (found by walking up from cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("SUBSTRAT_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Load + compile an artifact by name (e.g. "entropy_subset"),
    /// caching the compiled executable.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact: returns the decomposed output tuple.
    /// (All artifacts are lowered with return_tuple=True.)
    ///
    /// Inputs go through `buffer_from_host_buffer` + `execute_b` rather
    /// than `execute::<Literal>`: the crate's literal-based execute path
    /// leaks the device buffers it creates internally (~input size per
    /// call — found empirically; see EXPERIMENTS.md §Perf), while
    /// `PjRtBuffer`s we create ourselves are freed on drop.
    pub fn execute(&self, name: &str, inputs: &[ArgView]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|a| match a {
                ArgView::F32(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<f32>(data, dims, None)
                    .map_err(|e| anyhow!("uploading f32 input {dims:?}: {e:?}")),
                ArgView::I32(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None)
                    .map_err(|e| anyhow!("uploading i32 input {dims:?}: {e:?}")),
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .with_context(|| format!("executing artifact {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        lit.to_tuple().map_err(|e| anyhow!("decomposing {name} output: {e:?}"))
    }

    /// Artifact names available on disk.
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                e.file_name()
                    .to_str()
                    .and_then(|n| n.strip_suffix(".hlo.txt").map(str::to_string))
            })
            .collect();
        names.sort();
        names
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

thread_local! {
    static TL_RUNTIME: RefCell<Option<Rc<XlaRuntime>>> = const { RefCell::new(None) };
}

/// The calling thread's shared runtime (created on first use with the
/// default artifact directory).
pub fn thread_current() -> Result<Rc<XlaRuntime>> {
    TL_RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(rt) = slot.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Rc::new(XlaRuntime::new(XlaRuntime::default_dir())?);
        *slot = Some(rt.clone());
        Ok(rt)
    })
}

/// A borrowed typed input for one artifact execution (uploaded as a
/// device buffer; no intermediate Literal allocation).
pub enum ArgView<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
}

/// f32 input view with shape checking.
pub fn arg_f32<'a>(data: &'a [f32], dims: &[i64]) -> Result<ArgView<'a>> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "arg_f32: {} != {dims:?}", data.len());
    Ok(ArgView::F32(data, dims.iter().map(|&d| d as usize).collect()))
}

/// i32 input view with shape checking.
pub fn arg_i32<'a>(data: &'a [i32], dims: &[i64]) -> Result<ArgView<'a>> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "arg_i32: {} != {dims:?}", data.len());
    Ok(ArgView::I32(data, dims.iter().map(|&d| d as usize).collect()))
}

/// Unpack a literal into a Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Unpack a literal into a Vec<i32>.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}
