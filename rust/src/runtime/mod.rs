//! Artifact runtime: the execution layer behind every XLA-backed member
//! of the stack (entropy kernels, logreg/MLP train steps, k-means).
//!
//! Deployment shape (DESIGN.md §2): `python/compile/` AOT-lowers the
//! L1/L2 graphs to `artifacts/*.hlo.txt`, and a PJRT client executes
//! them from this hot path. Offline, neither the `xla` crate nor the
//! compiled artifacts are available, so this module follows the same
//! substrate rule as `util` (DESIGN.md §3.11): [`native`] implements the
//! artifact *contracts* in pure rust behind the identical `XlaRuntime`
//! API. Callers (`EntropyExec`, `ModelsExec`, the model zoo, baselines)
//! are byte-for-byte unchanged between the two execution paths; when the
//! PJRT path returns, the native interpreter stays as the reference the
//! compiled kernels are cross-checked against.
//!
//! Threading: a runtime instance is thread-confined (the PJRT client it
//! stands in for wraps an `Rc`); worker threads obtain their own via
//! [`thread_current`].

pub mod entropy_exec;
pub mod models_exec;
pub mod native;
pub mod shapes;

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::util::error::{Error, Result};

/// The artifact programs this runtime knows how to execute.
const ARTIFACTS: &[&str] = &[
    "entropy_subset",
    "entropy_batch",
    "entropy_columns",
    "logreg_train_step",
    "logreg_train_epoch",
    "logreg_predict",
    "mlp_train_step",
    "mlp_train_epoch",
    "mlp_predict",
    "kmeans_step",
];

/// A thread-confined artifact runtime. Construction never fails on the
/// native substrate; `dir` is where the compiled `*.hlo.txt` modules
/// would live (kept for `available()` and the manifest cross-checks).
pub struct XlaRuntime {
    dir: PathBuf,
}

impl XlaRuntime {
    /// Create a runtime reading artifacts from `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<XlaRuntime> {
        Ok(XlaRuntime {
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Default artifact directory: `$SUBSTRAT_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root (found by walking up from cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("SUBSTRAT_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Resolve an artifact by name ("compile" on the native substrate is
    /// a dispatch-table lookup; unknown names error like a missing HLO
    /// module would).
    pub fn load(&self, name: &str) -> Result<&'static str> {
        ARTIFACTS
            .iter()
            .find(|&&a| a == name)
            .copied()
            .ok_or_else(|| Error::msg(format!("unknown artifact {name:?}")))
    }

    /// Execute an artifact: returns the decomposed output tuple.
    /// (All artifacts are lowered with return_tuple=True; the native
    /// substrate returns the same tuple decomposition.)
    pub fn execute(&self, name: &str, inputs: &[ArgView]) -> Result<Vec<Literal>> {
        let name = self.load(name)?;
        match name {
            "entropy_subset" => {
                let h =
                    native::entropy_subset(i32s(inputs, 0)?, f32s(inputs, 1)?, f32s(inputs, 2)?);
                Ok(vec![Literal::F32(vec![h])])
            }
            "entropy_batch" => {
                let h = native::entropy_batch(i32s(inputs, 0)?, f32s(inputs, 1)?, f32s(inputs, 2)?);
                Ok(vec![Literal::F32(h)])
            }
            "entropy_columns" => {
                let h = native::entropy_columns(i32s(inputs, 0)?, f32s(inputs, 1)?);
                Ok(vec![Literal::F32(h)])
            }
            "logreg_train_step" | "logreg_train_epoch" => {
                let mut w = f32s(inputs, 4)?.to_vec();
                let mut b = f32s(inputs, 5)?.to_vec();
                let (lr, l2) = (scalar(inputs, 6)?, scalar(inputs, 7)?);
                let step = if name == "logreg_train_step" {
                    native::logreg_step
                } else {
                    native::logreg_epoch
                };
                let loss = step(
                    f32s(inputs, 0)?,
                    f32s(inputs, 1)?,
                    f32s(inputs, 2)?,
                    f32s(inputs, 3)?,
                    &mut w,
                    &mut b,
                    lr,
                    l2,
                );
                Ok(vec![
                    Literal::F32(w),
                    Literal::F32(b),
                    Literal::F32(vec![loss]),
                ])
            }
            "logreg_predict" => {
                let logits = native::logreg_predict(
                    f32s(inputs, 0)?,
                    f32s(inputs, 1)?,
                    f32s(inputs, 2)?,
                    f32s(inputs, 3)?,
                );
                Ok(vec![Literal::F32(logits)])
            }
            "mlp_train_step" | "mlp_train_epoch" => {
                let mut w1 = f32s(inputs, 4)?.to_vec();
                let mut b1 = f32s(inputs, 5)?.to_vec();
                let mut w2 = f32s(inputs, 6)?.to_vec();
                let mut b2 = f32s(inputs, 7)?.to_vec();
                let (lr, l2) = (scalar(inputs, 8)?, scalar(inputs, 9)?);
                let step = if name == "mlp_train_step" {
                    native::mlp_step
                } else {
                    native::mlp_epoch
                };
                let loss = step(
                    f32s(inputs, 0)?,
                    f32s(inputs, 1)?,
                    f32s(inputs, 2)?,
                    f32s(inputs, 3)?,
                    &mut w1,
                    &mut b1,
                    &mut w2,
                    &mut b2,
                    lr,
                    l2,
                );
                Ok(vec![
                    Literal::F32(w1),
                    Literal::F32(b1),
                    Literal::F32(w2),
                    Literal::F32(b2),
                    Literal::F32(vec![loss]),
                ])
            }
            "mlp_predict" => {
                let logits = native::mlp_predict(
                    f32s(inputs, 0)?,
                    f32s(inputs, 1)?,
                    f32s(inputs, 2)?,
                    f32s(inputs, 3)?,
                    f32s(inputs, 4)?,
                    f32s(inputs, 5)?,
                );
                Ok(vec![Literal::F32(logits)])
            }
            "kmeans_step" => {
                let (centroids, assign) =
                    native::kmeans_step(f32s(inputs, 0)?, f32s(inputs, 1)?, f32s(inputs, 2)?);
                Ok(vec![Literal::F32(centroids), Literal::I32(assign)])
            }
            _ => unreachable!("load() vetted the name"),
        }
    }

    /// Artifact names available on disk (the compiled `*.hlo.txt`
    /// modules; empty when the artifacts were never built).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                e.file_name()
                    .to_str()
                    .and_then(|n| n.strip_suffix(".hlo.txt").map(str::to_string))
            })
            .collect();
        names.sort();
        names
    }

    /// Execution platform description.
    pub fn platform(&self) -> String {
        "native-cpu (offline artifact interpreter)".to_string()
    }
}

thread_local! {
    static TL_RUNTIME: RefCell<Option<Rc<XlaRuntime>>> = const { RefCell::new(None) };
}

/// The calling thread's shared runtime (created on first use with the
/// default artifact directory).
pub fn thread_current() -> Result<Rc<XlaRuntime>> {
    TL_RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(rt) = slot.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Rc::new(XlaRuntime::new(XlaRuntime::default_dir())?);
        *slot = Some(rt.clone());
        Ok(rt)
    })
}

/// A borrowed typed input for one artifact execution.
pub enum ArgView<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
}

/// A typed output buffer (the substrate's `xla::Literal`).
#[derive(Debug, Clone)]
pub enum Literal {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// f32 input view with shape checking.
pub fn arg_f32<'a>(data: &'a [f32], dims: &[i64]) -> Result<ArgView<'a>> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "arg_f32: {} != {dims:?}", data.len());
    Ok(ArgView::F32(data, dims.iter().map(|&d| d as usize).collect()))
}

/// i32 input view with shape checking.
pub fn arg_i32<'a>(data: &'a [i32], dims: &[i64]) -> Result<ArgView<'a>> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "arg_i32: {} != {dims:?}", data.len());
    Ok(ArgView::I32(data, dims.iter().map(|&d| d as usize).collect()))
}

fn f32s<'a>(inputs: &'a [ArgView], idx: usize) -> Result<&'a [f32]> {
    match inputs.get(idx) {
        Some(ArgView::F32(data, _)) => Ok(data),
        Some(ArgView::I32(..)) => Err(Error::msg(format!("arg {idx}: expected f32, got i32"))),
        None => Err(Error::msg(format!("arg {idx}: missing"))),
    }
}

fn i32s<'a>(inputs: &'a [ArgView], idx: usize) -> Result<&'a [i32]> {
    match inputs.get(idx) {
        Some(ArgView::I32(data, _)) => Ok(data),
        Some(ArgView::F32(..)) => Err(Error::msg(format!("arg {idx}: expected i32, got f32"))),
        None => Err(Error::msg(format!("arg {idx}: missing"))),
    }
}

fn scalar(inputs: &[ArgView], idx: usize) -> Result<f32> {
    let v = f32s(inputs, idx)?;
    crate::ensure!(v.len() == 1, "arg {idx}: expected scalar, len {}", v.len());
    Ok(v[0])
}

/// Unpack a literal into a Vec<f32>.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    match lit {
        Literal::F32(v) => Ok(v.clone()),
        Literal::I32(_) => Err(Error::msg("to_vec f32: literal is i32")),
    }
}

/// Unpack a literal into a Vec<i32>.
pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
    match lit {
        Literal::I32(v) => Ok(v.clone()),
        Literal::F32(_) => Err(Error::msg("to_vec i32: literal is f32")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_artifact_name_loads() {
        let rt = XlaRuntime::new("artifacts").unwrap();
        for &name in ARTIFACTS {
            rt.load(name).unwrap();
        }
        assert!(rt.load("no_such_artifact").is_err());
    }

    #[test]
    fn arg_views_check_shapes() {
        assert!(arg_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(arg_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(arg_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
        assert!(arg_f32(&[1.0], &[]).is_ok(), "scalar: empty dims, len 1");
    }

    #[test]
    fn execute_rejects_wrong_arity_and_types() {
        let rt = XlaRuntime::new("artifacts").unwrap();
        let data = [1f32];
        let args = [ArgView::F32(&data, vec![1])];
        assert!(rt.execute("entropy_subset", &args).is_err());
    }
}
