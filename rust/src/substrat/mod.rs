//! SubStrat orchestrator (paper §1.1 + §3.4) — the three-step strategy:
//!
//! 1. find a measure-preserving data subset `d` (Gen-DST by default; any
//!    [`SubsetStrategy`] can be plugged in, which is how every baseline
//!    gets the identical treatment);
//! 2. run the AutoML tool on the subset: `A(d, y) -> M'`;
//! 3. fine-tune: re-run a restricted, much shorter AutoML on the full
//!    dataset, considering only the model family of `M'`, warm-started
//!    from `M'` itself, producing `M_sub`.
//!
//! `SubStrat-NF` (paper category F) is step 3 switched off.

use crate::automl::eval::EvalEngine;
use crate::automl::space::{ConfigSpace, PipelineConfig};
use crate::automl::{
    run_automl_with_engine, run_automl_with_engine_keyed, AutoMlConfig, AutoMlResult,
};
use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::data::{CodeMatrix, Frame};
use crate::gendst::default_dst_size;
use crate::gendst::pareto;
use crate::measures::DatasetMeasure;
use crate::util::timer::Stopwatch;

/// SubStrat knobs on top of an AutoML configuration.
#[derive(Clone)]
pub struct SubStratConfig {
    /// subset shape; None = the paper default (sqrt(N), 0.25 M)
    pub dst_size: Option<(usize, usize)>,
    /// run the restricted fine-tune pass (false = SubStrat-NF)
    pub fine_tune: bool,
    /// fine-tune budget as a fraction of the full AutoML eval budget
    pub fine_tune_frac: f64,
    /// per-objective weights selecting the operating point on the
    /// strategy's Pareto front (DESIGN.md §10). `None` — or a strategy
    /// with no front — keeps the strategy's own pick; a scalar Gen-DST
    /// run reports its winner as a one-point front, so selection is a
    /// no-op there by construction.
    pub operating_point: Option<Vec<f64>>,
    pub seed: u64,
}

impl Default for SubStratConfig {
    fn default() -> Self {
        SubStratConfig {
            dst_size: None,
            fine_tune: true,
            fine_tune_frac: 0.15,
            operating_point: None,
            seed: 0,
        }
    }
}

/// Full cost/quality accounting of one SubStrat run.
pub struct SubStratRun {
    /// the subset used
    pub outcome: StrategyOutcome,
    /// intermediate AutoML on the subset (M')
    pub automl_sub: AutoMlResult,
    /// restricted fine-tune on the full data (None for SubStrat-NF)
    pub fine_tune: Option<AutoMlResult>,
    /// the final configuration M_sub
    pub final_config: PipelineConfig,
    /// *raw* end-to-end wall clock (subset search + AutoML + fine-tune),
    /// **including** the strategy's `setup_s` harness overhead (MC-24H's
    /// budget probe). The paper's Time(M_sub) excludes that overhead,
    /// but the subtraction must match the measurement clock (wall vs
    /// CPU-proxy), so it lives in exactly one place — the measurement
    /// layer's [`crate::experiments::charged_time_s`] — never here (the
    /// seed subtracted wall `setup_s` here *and* let the runner subtract
    /// again from its own window, double-counting MC-24H's probe).
    pub total_time_s: f64,
    /// evaluations served from the eval memo shared across steps 2→3
    /// (the warm-start configuration alone guarantees ≥ 1 when
    /// fine-tuning runs; see DESIGN.md §5.1)
    pub eval_memo_hits: usize,
}

/// Run the SubStrat flow with an arbitrary subset strategy.
///
/// `automl_cfg` describes the *full* AutoML tool `A` (searcher, budget,
/// CV); SubStrat derives the subset and fine-tune runs from it.
pub fn run_substrat(
    frame: &Frame,
    codes: &CodeMatrix,
    measure: &dyn DatasetMeasure,
    strategy: &dyn SubsetStrategy,
    automl_cfg: &AutoMlConfig,
    cfg: &SubStratConfig,
) -> SubStratRun {
    let sw = Stopwatch::start();
    let (n, m) = cfg
        .dst_size
        .unwrap_or_else(|| default_dst_size(frame.n_rows, frame.n_cols()));

    // step 1: the data subset
    let ctx = StrategyContext {
        frame,
        codes,
        measure,
        n,
        m,
        seed: cfg.seed,
    };
    let mut outcome = strategy.find(&ctx);
    // step 1b: a caller-supplied operating point re-selects the subset
    // from the strategy's front (one multi-objective search serves any
    // number of operating points; the fidelity-only front is a single
    // point, so the scalar flow is untouched)
    if let Some(weights) = &cfg.operating_point {
        if let Some(i) = pareto::select_operating_point(&outcome.front, weights) {
            outcome.dst = outcome.front[i].dst.clone();
        }
    }
    let subset = frame.subset(&outcome.dst.rows, &outcome.dst.cols);

    // one evaluation engine spans steps 2 and 3. Its memo is keyed by
    // (dataset, config), so nothing scored on the subset can be served
    // to a full-frame evaluation (the PR 4 cross-dataset poisoning fix:
    // the seed's config-only memo handed any re-proposed fine-tune
    // configuration its *subset* score, letting the fine-tune argmax
    // pick on subset noise). The ONE deliberate carry-over — M' seeding
    // the fine-tune history with its subset score instead of paying a
    // full-frame CV fit up front — is made explicit below via
    // `seed_score` (documented approximation, DESIGN.md §5.1).
    let mut engine = EvalEngine::new(automl_cfg.policy.clone());

    // step 2: AutoML on the subset -> M'
    let mut sub_cfg = automl_cfg.clone();
    sub_cfg.seed = automl_cfg.seed ^ 0x5b;
    let automl_sub = run_automl_with_engine(&subset, &sub_cfg, &mut engine);

    // step 3: restricted fine-tune on the full dataset -> M_sub
    let fine_tune = if cfg.fine_tune {
        let mut ft_cfg = automl_cfg.clone();
        ft_cfg.space = ConfigSpace::restricted_to(automl_sub.best.model.kind());
        ft_cfg.max_evals = ((automl_cfg.max_evals as f64 * cfg.fine_tune_frac).round()
            as usize)
            .max(1);
        ft_cfg.warm_start = vec![automl_sub.best.clone()];
        ft_cfg.seed = automl_cfg.seed ^ 0xf1;
        // the full frame's content key, computed ONCE and threaded into
        // the fine-tune run below — the seed fingerprinted the full
        // frame here AND again inside the fine-tune run, charging an
        // extra O(n·m) pass to the timed window (regression:
        // full_frame_is_fingerprinted_once_per_run)
        let full_key = crate::automl::eval::frame_key(frame);
        // the explicit warm-start carry-over: M' enters the fine-tune
        // run — under the FULL frame's key, the fine-tune run's own
        // seed and fold count — carrying its subset score
        engine.seed_score(
            full_key,
            ft_cfg.seed,
            ft_cfg.cv_folds,
            &automl_sub.best,
            automl_sub.best_cv,
        );
        Some(run_automl_with_engine_keyed(frame, &ft_cfg, &mut engine, Some(full_key)))
    } else {
        None
    };

    let final_config = fine_tune
        .as_ref()
        .map(|ft| ft.best.clone())
        .unwrap_or_else(|| automl_sub.best.clone());

    let total_time_s = sw.elapsed_s();
    SubStratRun {
        outcome,
        automl_sub,
        fine_tune,
        final_config,
        total_time_s,
        eval_memo_hits: engine.memo_hits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automl::SearcherKind;
    use crate::baselines;
    use crate::data::registry;
    use crate::measures::entropy::EntropyMeasure;

    fn setup() -> (Frame, CodeMatrix) {
        let f = registry::load("D2", 0.04, 17);
        let codes = CodeMatrix::from_frame(&f);
        (f, codes)
    }

    #[test]
    fn full_flow_with_fine_tune() {
        let (f, codes) = setup();
        let strategy = baselines::by_name("gendst");
        let automl = AutoMlConfig::new(SearcherKind::Random, 6, 1);
        let cfg = SubStratConfig {
            fine_tune_frac: 0.5,
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        // fine-tune restricted to M' family and warm-started from it
        let ft = run.fine_tune.as_ref().unwrap();
        assert_eq!(ft.history[0].0, run.automl_sub.best);
        for (c, _) in &ft.history {
            assert_eq!(c.model.kind(), run.automl_sub.best.model.kind());
        }
        assert_eq!(ft.evals, 3);
        assert_eq!(run.final_config, ft.best);
        assert!(run.total_time_s > 0.0);
    }

    #[test]
    fn eval_memo_shared_across_steps_saves_evals() {
        // the warm-start config M' is scored in step 2; step 3 must
        // serve its head-of-history evaluation from the shared memo
        // instead of paying a second CV fit
        let (f, codes) = setup();
        let strategy = baselines::by_name("gendst");
        let automl = AutoMlConfig::new(SearcherKind::Random, 6, 9);
        let cfg = SubStratConfig {
            fine_tune_frac: 0.5,
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        let ft = run.fine_tune.as_ref().unwrap();
        assert!(run.eval_memo_hits >= 1, "warm start missed the shared memo");
        assert!(ft.memo_hits >= 1, "fine-tune run paid for the warm start again");
        // the served score is the warm config's step-2 score, bit-exact
        assert_eq!(ft.history[0].1.to_bits(), run.automl_sub.best_cv.to_bits());
    }

    #[test]
    fn fine_tune_re_proposals_are_scored_on_the_full_frame() {
        // PR 4 headline regression at the flow level: every fine-tune
        // history entry EXCEPT the seeded warm start must carry the
        // score a fresh full-frame evaluation of that configuration
        // yields — before the (dataset, config) memo key, a re-proposed
        // configuration was served its subset score instead
        use crate::automl::eval::{cv_score_planned, FoldPlan};
        let (f, codes) = setup();
        let strategy = baselines::by_name("gendst");
        let automl = AutoMlConfig::new(SearcherKind::Random, 8, 13);
        let cfg = SubStratConfig {
            fine_tune_frac: 0.75, // a long fine-tune: re-proposals likely
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        let ft = run.fine_tune.as_ref().unwrap();
        let ft_seed = automl.seed ^ 0xf1;
        let plan = FoldPlan::new(&f, automl.cv_folds, ft_seed);
        for (i, (c, s)) in ft.history.iter().enumerate().skip(1) {
            if *c == run.automl_sub.best {
                // a re-proposal of M' itself rides the explicit seeded
                // carry-over, like the head entry
                assert_eq!(s.to_bits(), run.automl_sub.best_cv.to_bits());
                continue;
            }
            let want = cv_score_planned(c, &f, &plan, ft_seed, None);
            assert_eq!(
                s.to_bits(),
                want.to_bits(),
                "fine-tune history[{i}] not scored on the full frame"
            );
        }
        // the seeded head is the one deliberate exception
        assert_eq!(ft.history[0].1.to_bits(), run.automl_sub.best_cv.to_bits());
    }

    #[test]
    fn full_frame_is_fingerprinted_once_per_run() {
        // PR 4 follow-up: frame_key(full) was computed twice inside the
        // timed window (once for seed_score, once inside the fine-tune
        // run), charging an extra O(n·m) content pass to time_sub_s.
        // One SubStrat run now pays exactly one pass per distinct
        // frame: the subset and (when fine-tuning) the full frame.
        use crate::automl::eval::frame_key_passes;
        let (f, codes) = setup();
        let strategy = baselines::by_name("gendst");
        let automl = AutoMlConfig::new(SearcherKind::Random, 6, 21);
        let cfg = SubStratConfig {
            fine_tune_frac: 0.5,
            ..Default::default()
        };
        let before = frame_key_passes();
        let _ = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        assert_eq!(
            frame_key_passes() - before,
            2,
            "expected exactly two passes: the subset and the full frame"
        );
        let nf = SubStratConfig {
            fine_tune: false,
            ..Default::default()
        };
        let before = frame_key_passes();
        let _ = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &nf);
        assert_eq!(
            frame_key_passes() - before,
            1,
            "SubStrat-NF touches only the subset frame"
        );
    }

    #[test]
    fn mc24h_setup_time_counts_once_in_raw_total() {
        // total_time_s is RAW: it contains the MC-24H budget probe's
        // setup window exactly once, and the single mode-matching
        // subtraction happens in experiments::charged_time_s — never
        // here (the seed subtracted in both places)
        let (f, codes) = setup();
        let strategy = baselines::by_name("mc-24h");
        let automl = AutoMlConfig::new(SearcherKind::Random, 4, 6);
        let cfg = SubStratConfig {
            fine_tune: false,
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        assert!(run.outcome.setup_s > 0.0, "mc-24h must report a probe window");
        // the probe, the MC search and the subset AutoML are disjoint
        // sub-intervals of the raw window — if setup had been
        // subtracted here, this sum could exceed the total
        let parts = run.outcome.setup_s + run.outcome.elapsed_s + run.automl_sub.elapsed_s;
        assert!(
            run.total_time_s >= parts - 1e-6,
            "raw total {} lost a sub-window (parts sum {parts})",
            run.total_time_s
        );
    }

    #[test]
    fn nf_variant_skips_fine_tune() {
        let (f, codes) = setup();
        let strategy = baselines::by_name("gendst");
        let automl = AutoMlConfig::new(SearcherKind::Random, 4, 2);
        let cfg = SubStratConfig {
            fine_tune: false,
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        assert!(run.fine_tune.is_none());
        assert_eq!(run.final_config, run.automl_sub.best);
    }

    #[test]
    fn custom_dst_size_is_used() {
        let (f, codes) = setup();
        let strategy = baselines::by_name("mc-100");
        let automl = AutoMlConfig::new(SearcherKind::Random, 3, 3);
        let cfg = SubStratConfig {
            dst_size: Some((25, 3)),
            fine_tune: false,
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        assert_eq!(run.outcome.dst.rows.len(), 25);
        assert_eq!(run.outcome.dst.cols.len(), 3);
    }

    #[test]
    fn operating_point_reselects_subset_from_the_front() {
        use crate::gendst::pareto::Objective;
        let (f, codes) = setup();
        let objs = [
            Objective::Fidelity,
            Objective::SubsetSize,
            Objective::DownstreamTime,
        ];
        let strategy = baselines::by_name_configured("gendst", 1, 1, &objs);
        let automl = AutoMlConfig::new(SearcherKind::Random, 3, 5);
        // a pure size weight (missing trailing weights default to 0)
        // must pick the smallest subset on the front — and that subset,
        // not the fidelity winner, is what the AutoML step sees
        let cfg = SubStratConfig {
            fine_tune: false,
            operating_point: Some(vec![0.0, 1.0]),
            ..Default::default()
        };
        let run = run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
        assert!(!run.outcome.front.is_empty(), "MO gendst must report a front");
        let area = |d: &crate::gendst::Dst| d.rows.len() * d.cols.len();
        let min_area = run.outcome.front.iter().map(|p| area(&p.dst)).min().unwrap();
        assert_eq!(area(&run.outcome.dst), min_area, "size weight must pick the smallest");
        assert!(
            run.outcome.front.iter().any(|p| p.dst == run.outcome.dst),
            "the selected subset must be a front member"
        );
    }

    #[test]
    fn operating_point_is_a_no_op_without_a_real_front() {
        // scalar Gen-DST reports a one-point front (selection picks that
        // same point); frontless baselines keep their own dst
        let (f, codes) = setup();
        let automl = AutoMlConfig::new(SearcherKind::Random, 3, 5);
        for name in ["gendst", "mc-100"] {
            let strategy = baselines::by_name(name);
            let plain = SubStratConfig {
                fine_tune: false,
                ..Default::default()
            };
            let weighted = SubStratConfig {
                operating_point: Some(vec![1.0, 2.0]),
                ..plain.clone()
            };
            let a =
                run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &plain);
            let b = run_substrat(
                &f,
                &codes,
                &EntropyMeasure,
                strategy.as_ref(),
                &automl,
                &weighted,
            );
            assert_eq!(a.outcome.dst, b.outcome.dst, "{name}");
        }
    }

    #[test]
    fn works_with_baseline_strategies() {
        let (f, codes) = setup();
        for name in ["ig-rand", "mab"] {
            let strategy = baselines::by_name(name);
            let automl = AutoMlConfig::new(SearcherKind::Random, 3, 4);
            let cfg = SubStratConfig {
                fine_tune: true,
                fine_tune_frac: 0.4,
                ..Default::default()
            };
            let run =
                run_substrat(&f, &codes, &EntropyMeasure, strategy.as_ref(), &automl, &cfg);
            assert!(run.fine_tune.is_some(), "{name}");
        }
    }
}
