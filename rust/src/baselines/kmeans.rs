//! Category D — clustering-based subset selection (paper §4.2): k-means
//! rows to n clusters and columns to m clusters, picking the members
//! closest to each centroid. Lloyd iterations execute through the
//! AOT-compiled `kmeans_step` artifact on PJRT, streamed in
//! KM_POINTS-sized tiles (mini-batch accumulation on the rust side).
//!
//! Documented approximation (DESIGN.md §5): the artifact carries KM_K=32
//! centroid slots, so for n > 32 we cluster into 32 groups and take a
//! per-cluster quota of nearest members instead of n singleton clusters —
//! same selection principle, bounded artifact shape.

use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::data::Frame;
use crate::gendst::Dst;
use crate::runtime::models_exec::ModelsExec;
use crate::runtime::shapes::{KM_DIM, KM_K, KM_POINTS};
use crate::runtime::{self};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// far-away coordinate that disables unused centroid slots
const FAR: f32 = 1e6;

pub struct KmStrategy {
    pub lloyd_iters: usize,
}

impl Default for KmStrategy {
    fn default() -> Self {
        KmStrategy { lloyd_iters: 4 }
    }
}

/// Row embedding: up to KM_DIM highest-variance feature columns,
/// z-scored. Returns (embedded points, used column indices).
fn embed_rows(frame: &Frame) -> Vec<f32> {
    let feats = frame.feature_indices();
    let mut by_var: Vec<(u32, f64)> = feats
        .iter()
        .map(|&c| {
            let v = &frame.columns[c as usize].values;
            let m = v.iter().map(|&x| x as f64).sum::<f64>() / v.len().max(1) as f64;
            let var = v.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>()
                / v.len().max(1) as f64;
            (c, var)
        })
        .collect();
    by_var.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let used: Vec<u32> = by_var.iter().take(KM_DIM).map(|&(c, _)| c).collect();

    let n = frame.n_rows;
    let mut pts = vec![0f32; n * KM_DIM];
    for (j, &c) in used.iter().enumerate() {
        let col = &frame.columns[c as usize].values;
        let m = col.iter().map(|&x| x as f64).sum::<f64>() / n.max(1) as f64;
        let sd = (col.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n.max(1) as f64)
            .sqrt()
            .max(1e-9);
        for r in 0..n {
            pts[r * KM_DIM + j] = ((col[r] as f64 - m) / sd) as f32;
        }
    }
    pts
}

/// Streaming Lloyd over `points` (row-major, KM_DIM wide): returns final
/// centroids and per-point assignment. `k <= KM_K` active centroids.
fn lloyd(
    points: &[f32],
    n_points: usize,
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<u32>) {
    let rt = runtime::thread_current().expect("PJRT runtime unavailable — run `make artifacts`");
    let exec = ModelsExec::new(&rt);

    // init: k random points, unused slots pushed far away
    let mut centroids = vec![FAR; KM_K * KM_DIM];
    for c in 0..k {
        let r = rng.usize_below(n_points);
        centroids[c * KM_DIM..(c + 1) * KM_DIM]
            .copy_from_slice(&points[r * KM_DIM..(r + 1) * KM_DIM]);
    }

    let mut assign = vec![0u32; n_points];
    for _it in 0..iters {
        let mut sums = vec![0f64; k * KM_DIM];
        let mut counts = vec![0u64; k];
        let mut tile = vec![0f32; KM_POINTS * KM_DIM];
        let mut pmask = vec![0f32; KM_POINTS];
        let mut base = 0usize;
        while base < n_points {
            let take = KM_POINTS.min(n_points - base);
            tile.fill(0.0);
            pmask.fill(0.0);
            tile[..take * KM_DIM]
                .copy_from_slice(&points[base * KM_DIM..(base + take) * KM_DIM]);
            pmask[..take].fill(1.0);
            let (_, a) = exec
                .kmeans_step(&tile, &pmask, &centroids)
                .expect("kmeans_step artifact failed");
            for i in 0..take {
                let c = (a[i] as usize).min(k - 1);
                assign[base + i] = c as u32;
                counts[c] += 1;
                for j in 0..KM_DIM {
                    sums[c * KM_DIM + j] += points[(base + i) * KM_DIM + j] as f64;
                }
            }
            base += take;
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..KM_DIM {
                    centroids[c * KM_DIM + j] = (sums[c * KM_DIM + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    (centroids, assign)
}

/// Pick `want` member indices: per-cluster quotas of nearest-to-centroid
/// members (cluster sizes pro-rated, remainders filled globally).
fn pick_representatives(
    points: &[f32],
    assign: &[u32],
    centroids: &[f32],
    k: usize,
    want: usize,
) -> Vec<u32> {
    let n = assign.len();
    // distance of each point to its centroid
    let mut by_cluster: Vec<Vec<(f32, u32)>> = vec![Vec::new(); k];
    for i in 0..n {
        let c = assign[i] as usize;
        let mut d = 0f32;
        for j in 0..KM_DIM {
            let diff = points[i * KM_DIM + j] - centroids[c * KM_DIM + j];
            d += diff * diff;
        }
        by_cluster[c].push((d, i as u32));
    }
    for members in by_cluster.iter_mut() {
        members.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    }
    let mut picked: Vec<u32> = Vec::with_capacity(want);
    // proportional quotas
    let mut cursor = vec![0usize; k];
    for c in 0..k {
        let quota = (want * by_cluster[c].len()).div_euclid(n.max(1));
        for &(_, i) in by_cluster[c].iter().take(quota) {
            picked.push(i);
            cursor[c] = quota;
        }
    }
    // fill remainder round-robin by next-nearest members
    let mut c = 0usize;
    while picked.len() < want {
        if cursor[c] < by_cluster[c].len() {
            picked.push(by_cluster[c][cursor[c]].1);
            cursor[c] += 1;
        }
        c = (c + 1) % k;
        // safety: if all clusters exhausted (shouldn't happen), break
        if cursor.iter().zip(&by_cluster).all(|(&u, m)| u >= m.len()) {
            break;
        }
    }
    picked.truncate(want);
    picked
}

/// Public entry used by both KM and IG-KM: cluster rows, return `n`
/// representative row indices.
pub fn kmeans_rows(frame: &Frame, n: usize, lloyd_iters: usize, rng: &mut Rng) -> Vec<u32> {
    let pts = embed_rows(frame);
    let k = KM_K.min(n).max(1);
    let (centroids, assign) = lloyd(&pts, frame.n_rows, k, lloyd_iters, rng);
    pick_representatives(&pts, &assign, &centroids, k, n)
}

/// Cluster feature columns (embedded as KM_DIM sampled, z-scored row
/// values) into m-1 groups; return the nearest column per group plus the
/// target column.
pub fn kmeans_cols(frame: &Frame, m: usize, lloyd_iters: usize, rng: &mut Rng) -> Vec<u32> {
    let feats = frame.feature_indices();
    let n_rows = frame.n_rows;
    // sample KM_DIM row positions shared by all columns
    let sample: Vec<usize> = (0..KM_DIM)
        .map(|_| rng.usize_below(n_rows))
        .collect();
    let mut pts = vec![0f32; feats.len() * KM_DIM];
    for (i, &c) in feats.iter().enumerate() {
        let col = &frame.columns[c as usize].values;
        let mvals: Vec<f64> = sample.iter().map(|&r| col[r] as f64).collect();
        let mean = mvals.iter().sum::<f64>() / mvals.len() as f64;
        let sd = (mvals.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / mvals.len() as f64)
            .sqrt()
            .max(1e-9);
        for (j, &v) in mvals.iter().enumerate() {
            pts[i * KM_DIM + j] = ((v - mean) / sd) as f32;
        }
    }
    let k = (m - 1).clamp(1, KM_K.min(feats.len()));
    let (centroids, assign) = lloyd(&pts, feats.len(), k, lloyd_iters, rng);
    let reps = pick_representatives(&pts, &assign, &centroids, k, m - 1);
    let mut cols: Vec<u32> = reps.iter().map(|&i| feats[i as usize]).collect();
    cols.sort_unstable();
    cols.dedup();
    // pad with unused features if clustering collapsed
    for &f in &feats {
        if cols.len() >= m - 1 {
            break;
        }
        if !cols.contains(&f) {
            cols.push(f);
        }
    }
    cols.push(frame.target as u32);
    cols
}

impl SubsetStrategy for KmStrategy {
    fn name(&self) -> &'static str {
        "km"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let rows = kmeans_rows(ctx.frame, ctx.n, self.lloyd_iters, &mut rng);
        let cols = kmeans_cols(ctx.frame, ctx.m, self.lloyd_iters, &mut rng);
        StrategyOutcome {
            dst: Dst { rows, cols },
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: 0,
            front: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::{registry, CodeMatrix};
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn km_outputs_valid_dst() {
        let f = registry::load("D3", 0.06, 8);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 23);
        let out = KmStrategy::default().find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(out.dst.rows.len(), ctx.n);
        assert_eq!(out.dst.cols.len(), ctx.m);
    }

    #[test]
    fn representatives_cover_distinct_clusters() {
        // two well-separated blobs: representatives must come from both
        let mut pts = vec![0f32; 200 * KM_DIM];
        for i in 0..200 {
            let off = if i < 100 { -5.0 } else { 5.0 };
            for j in 0..2 {
                pts[i * KM_DIM + j] = off;
            }
        }
        let assign: Vec<u32> = (0..200).map(|i| (i >= 100) as u32).collect();
        let mut centroids = vec![0f32; KM_K * KM_DIM];
        centroids[0] = -5.0;
        centroids[1] = -5.0;
        centroids[KM_DIM] = 5.0;
        centroids[KM_DIM + 1] = 5.0;
        let picked = pick_representatives(&pts, &assign, &centroids, 2, 10);
        assert_eq!(picked.len(), 10);
        let low = picked.iter().filter(|&&i| i < 100).count();
        assert!(low >= 3 && low <= 7, "unbalanced picks: {low}/10");
    }

    #[test]
    fn kmeans_rows_returns_distinct_indices() {
        let f = registry::load("D2", 0.05, 9);
        let mut rng = Rng::new(3);
        let rows = kmeans_rows(&f, 40, 2, &mut rng);
        let mut r = rows.clone();
        r.sort_unstable();
        r.dedup();
        assert_eq!(r.len(), 40);
        assert!(r.iter().all(|&x| (x as usize) < f.n_rows));
    }
}
