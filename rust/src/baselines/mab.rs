//! Category B — Multi-Arm Bandit (paper §4.2): row-arms and column-arms
//! with ε-greedy exploration. Each round assembles a subset from the
//! currently best-valued arms (with ε-probability random picks),
//! evaluates the measure-preservation loss, and credits every arm used
//! with the reward `-loss` (incremental mean update).

use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::gendst::{fitness::FitnessBackend, fitness::FitnessEval, Dst};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub struct MultiArmBandit {
    pub rounds: usize,
    pub epsilon: f64,
}

impl Default for MultiArmBandit {
    fn default() -> Self {
        MultiArmBandit {
            rounds: 300,
            epsilon: 0.15,
        }
    }
}

struct Arms {
    value: Vec<f64>,
    pulls: Vec<u32>,
}

impl Arms {
    fn new(n: usize) -> Arms {
        Arms {
            value: vec![0.0; n],
            pulls: vec![0; n],
        }
    }

    /// Pick `k` distinct arms: each slot is ε-random, otherwise the best
    /// unpicked arm by value estimate (unpulled arms count as optimistic).
    fn pick(&self, k: usize, eps: f64, rng: &mut Rng, exclude: Option<u32>) -> Vec<u32> {
        let n = self.value.len();
        let mut order: Vec<usize> = (0..n).collect();
        // optimistic init: unpulled arms rank first, then by value
        order.sort_by(|&a, &b| {
            let ka = (self.pulls[a] == 0, self.value[a]);
            let kb = (self.pulls[b] == 0, self.value[b]);
            kb.partial_cmp(&ka).unwrap()
        });
        let mut picked: Vec<u32> = Vec::with_capacity(k);
        let mut cursor = 0usize;
        while picked.len() < k {
            let cand = if rng.bool_with(eps) {
                rng.u64_below(n as u64) as u32
            } else {
                // next best not yet picked
                while cursor < n
                    && (picked.contains(&(order[cursor] as u32))
                        || Some(order[cursor] as u32) == exclude)
                {
                    cursor += 1;
                }
                if cursor >= n {
                    rng.u64_below(n as u64) as u32
                } else {
                    order[cursor] as u32
                }
            };
            if Some(cand) != exclude && !picked.contains(&cand) {
                picked.push(cand);
            }
        }
        picked
    }

    fn update(&mut self, arm: u32, reward: f64) {
        let i = arm as usize;
        self.pulls[i] += 1;
        let n = self.pulls[i] as f64;
        self.value[i] += (reward - self.value[i]) / n;
    }
}

impl SubsetStrategy for MultiArmBandit {
    fn name(&self) -> &'static str {
        "mab"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let mut eval =
            FitnessEval::new(ctx.frame, ctx.codes, ctx.measure, FitnessBackend::NaiveNative);
        let target = ctx.frame.target as u32;

        let mut row_arms = Arms::new(ctx.frame.n_rows);
        let mut col_arms = Arms::new(ctx.frame.n_cols());

        let mut best: Option<(f64, Dst)> = None;
        for _round in 0..self.rounds {
            let rows = row_arms.pick(ctx.n, self.epsilon, &mut rng, None);
            let mut cols = col_arms.pick(ctx.m - 1, self.epsilon, &mut rng, Some(target));
            cols.push(target);
            let loss = eval.loss(&rows, &cols);
            let reward = -loss;
            for &r in &rows {
                row_arms.update(r, reward);
            }
            for &c in &cols {
                if c != target {
                    col_arms.update(c, reward);
                }
            }
            if best.as_ref().map_or(true, |(bl, _)| loss < *bl) {
                best = Some((loss, Dst { rows, cols }));
            }
        }
        let (_, dst) = best.unwrap();
        StrategyOutcome {
            dst,
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: eval.evals,
            front: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::{registry, CodeMatrix};
    use crate::gendst::ops::random_candidate;
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn beats_mean_random_subset() {
        let f = registry::load("D2", 0.05, 5);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 11);
        let out = MultiArmBandit::default().find(&ctx);
        let mut eval = FitnessEval::new(&f, &codes, &m, FitnessBackend::NaiveNative);
        let mab_loss = eval.loss(&out.dst.rows, &out.dst.cols);

        let mut rng = Rng::new(77);
        let mut rand_losses = Vec::new();
        for _ in 0..50 {
            let c = random_candidate(&f, ctx.n, ctx.m, &mut rng);
            rand_losses.push(eval.loss(&c.rows, &c.cols));
        }
        let mean_rand = crate::util::stats::mean(&rand_losses);
        assert!(mab_loss < mean_rand, "MAB {mab_loss} vs random {mean_rand}");
    }

    #[test]
    fn arms_update_moves_value_toward_reward() {
        let mut arms = Arms::new(3);
        arms.update(0, -1.0);
        arms.update(0, -3.0);
        assert!((arms.value[0] + 2.0).abs() < 1e-12);
        assert_eq!(arms.pulls[0], 2);
    }

    #[test]
    fn pick_excludes_and_dedups() {
        let mut rng = Rng::new(13);
        let arms = Arms::new(10);
        for _ in 0..20 {
            let picked = arms.pick(5, 0.5, &mut rng, Some(3));
            assert_eq!(picked.len(), 5);
            assert!(!picked.contains(&3));
            let mut p = picked.clone();
            p.sort_unstable();
            p.dedup();
            assert_eq!(p.len(), 5);
        }
    }
}
