//! Baseline subset-generation strategies (paper §4.2, Table 3).
//! Every strategy — including Gen-DST itself — implements
//! [`SubsetStrategy`]: given a frame it returns a DST of size (n, m),
//! and the SubStrat orchestrator (substrat/) runs the identical
//! AutoML + fine-tune flow on whatever subset came out. That isolation is
//! exactly the paper's comparison design.
//!
//! Category map (Table 3): A = mc (MC-100 / MC-100K / MC-24H),
//! B = mab, C = greedy (Greedy-Seq / Greedy-Mult), D = kmeans (KM),
//! E = ig (IG-Rand, IG-KM), F = SubStrat-NF (a SubStrat flag, §substrat).

pub mod greedy;
pub mod ig;
pub mod kmeans;
pub mod mab;
pub mod mc;

use crate::data::{CodeMatrix, Frame};
use crate::gendst::pareto::{Objective, ParetoPoint};
use crate::gendst::{self, Dst, GenDstConfig};
use crate::measures::DatasetMeasure;
use crate::util::timer::Stopwatch;

/// Everything a strategy may use to build its subset.
pub struct StrategyContext<'a> {
    pub frame: &'a Frame,
    pub codes: &'a CodeMatrix,
    pub measure: &'a dyn DatasetMeasure,
    /// requested subset shape
    pub n: usize,
    pub m: usize,
    pub seed: u64,
}

/// Outcome: the subset plus cost accounting.
#[derive(Debug, Clone)]
pub struct StrategyOutcome {
    pub dst: Dst,
    /// wall clock of the subset *search* itself — the window that enters
    /// the paper's Time(M_sub)
    pub elapsed_s: f64,
    /// harness overhead spent before the timed window opened (MC-24H's
    /// budget-estimation probe; 0 for every other strategy). Excluded
    /// from `elapsed_s` and from SubStrat's `total_time_s`.
    pub setup_s: f64,
    /// the same setup window measured in CPU time (own thread + billed
    /// pool workers; equals wall where no thread CPU clock exists). The
    /// runner's `CpuProxy` mode subtracts *this* — subtracting the wall
    /// figure from a CPU measurement would over-correct under
    /// contention.
    pub setup_cpu_s: f64,
    /// measure/fitness evaluations spent (0 where not applicable)
    pub evals: usize,
    /// the Pareto front of the subset search (DESIGN.md §10). Scalar
    /// Gen-DST reports its winner as a one-point front; baselines that
    /// have no notion of a front leave this empty. `dst` is always the
    /// strategy's own pick — SubStrat step 1 may re-select from here
    /// when the caller supplies an operating point.
    pub front: Vec<ParetoPoint>,
}

pub trait SubsetStrategy: Sync {
    fn name(&self) -> &'static str;
    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome;
}

/// Gen-DST wrapped as a strategy (the SubStrat default).
pub struct GenDstStrategy {
    pub config: GenDstConfig,
}

impl SubsetStrategy for GenDstStrategy {
    fn name(&self) -> &'static str {
        "gendst"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut cfg = self.config.clone();
        cfg.seed = ctx.seed;
        let res = gendst::gen_dst(ctx.frame, ctx.codes, ctx.measure, ctx.n, ctx.m, &cfg);
        StrategyOutcome {
            dst: res.dst,
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: res.fitness_evals,
            front: res.front,
        }
    }
}

/// Strategy registry by CLI/experiment name, with the default engine
/// thread knobs (Gen-DST auto-sizes its fitness fills to the hardware).
pub fn by_name(name: &str) -> Box<dyn SubsetStrategy> {
    by_name_threaded(name, 0)
}

/// [`by_name_with`] at the default (single-population) island count.
pub fn by_name_threaded(name: &str, threads: usize) -> Box<dyn SubsetStrategy> {
    by_name_with(name, threads, GenDstConfig::default().islands)
}

/// Strategy registry with an explicit inner-engine thread budget and
/// Gen-DST island count. The experiment runner passes its per-cell
/// `inner` allowance here so a strategy's own parallelism (the Gen-DST
/// island engine and its fitness fills) stays inside the two-level
/// budget instead of grabbing every core (DESIGN.md §5.2), and its
/// pinned `islands` so every cell — including the MC-24H budget
/// probe — searches with the same engine shape (§4.6). `threads = 0`
/// means auto; `islands` is results-changing and is pinned explicitly
/// (never thread-derived) wherever records are compared across
/// machines.
pub fn by_name_with(name: &str, threads: usize, islands: usize) -> Box<dyn SubsetStrategy> {
    by_name_configured(name, threads, islands, &[Objective::Fidelity])
}

/// [`by_name_with`] plus the Gen-DST objective vector (DESIGN.md §10).
/// `[Fidelity]` keeps every strategy on the scalar paper engine; a
/// longer vector switches the Gen-DST cells (and the MC-24H budget
/// probe, which must cost out the same engine) to the NSGA-II path.
pub fn by_name_configured(
    name: &str,
    threads: usize,
    islands: usize,
    objectives: &[Objective],
) -> Box<dyn SubsetStrategy> {
    match name {
        "gendst" | "substrat" => Box::new(GenDstStrategy {
            config: GenDstConfig {
                threads,
                islands,
                objectives: objectives.to_vec(),
                ..Default::default()
            },
        }),
        "mc-100" => Box::new(mc::MonteCarlo {
            instance: "mc-100",
            max_evals: 100,
            time_mult_of_gendst: None,
            probe_threads: threads,
            probe_islands: islands,
            probe_objectives: objectives.to_vec(),
        }),
        "mc-100k" => Box::new(mc::MonteCarlo {
            instance: "mc-100k",
            max_evals: 100_000,
            time_mult_of_gendst: None,
            probe_threads: threads,
            probe_islands: islands,
            probe_objectives: objectives.to_vec(),
        }),
        // MC-24H: budget-scaled stand-in — 20x the wall-clock Gen-DST
        // needs on the same input (see DESIGN.md §5). The probe runs
        // with this cell's own thread/island allowance so the
        // extrapolated budget matches what the real Gen-DST cell costs
        // here.
        "mc-24h" => Box::new(mc::MonteCarlo {
            instance: "mc-24h",
            max_evals: usize::MAX,
            time_mult_of_gendst: Some(20.0),
            probe_threads: threads,
            probe_islands: islands,
            probe_objectives: objectives.to_vec(),
        }),
        "mab" => Box::new(mab::MultiArmBandit::default()),
        "greedy-seq" => Box::new(greedy::GreedySeq::default()),
        "greedy-mult" => Box::new(greedy::GreedyMult::default()),
        "km" => Box::new(kmeans::KmStrategy::default()),
        "ig-rand" => Box::new(ig::IgRand),
        "ig-km" => Box::new(ig::IgKm::default()),
        other => panic!(
            "unknown strategy {other:?} \
             (gendst|mc-100|mc-100k|mc-24h|mab|greedy-seq|greedy-mult|km|ig-rand|ig-km)"
        ),
    }
}

/// All Table-4 strategy names (greedy variants excluded, as in the paper:
/// their full-scale runs exceeded the 24h cut-off and were omitted).
pub fn table4_strategies() -> Vec<&'static str> {
    vec![
        "gendst", "ig-km", "mab", "ig-rand", "km", "mc-100k", "mc-100",
    ]
}

#[cfg(test)]
pub(crate) fn test_ctx<'a>(
    frame: &'a Frame,
    codes: &'a CodeMatrix,
    measure: &'a dyn DatasetMeasure,
    seed: u64,
) -> StrategyContext<'a> {
    let (n, m) = gendst::default_dst_size(frame.n_rows, frame.n_cols());
    StrategyContext {
        frame,
        codes,
        measure,
        n,
        m,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn registry_resolves_every_name_and_outputs_valid_dst() {
        let f = registry::load("D2", 0.03, 1);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        for name in [
            "gendst", "mc-100", "mab", "greedy-seq", "greedy-mult", "km", "ig-rand", "ig-km",
        ] {
            let s = by_name(name);
            assert!(name.starts_with(s.name()), "{} vs {name}", s.name());
            let ctx = test_ctx(&f, &codes, &m, 42);
            let out = s.find(&ctx);
            out.dst
                .validate(f.n_rows, f.n_cols(), f.target)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(out.dst.rows.len(), ctx.n, "{name} row count");
            assert_eq!(out.dst.cols.len(), ctx.m, "{name} col count");
        }
    }

    #[test]
    #[should_panic(expected = "unknown strategy")]
    fn unknown_strategy_panics() {
        let _ = by_name("nope");
    }

    #[test]
    fn mc_instances_carry_distinct_names() {
        // regression: all three paper MC instances reported name() ==
        // "mc", making StrategyOutcome labels and logs ambiguous
        for name in ["mc-100", "mc-100k", "mc-24h"] {
            let s = by_name(name);
            assert_eq!(s.name(), name);
            assert_ne!(
                crate::experiments::paper_label(s.name()),
                "?",
                "{name} has no paper label"
            );
        }
        let names: Vec<&str> = ["mc-100", "mc-100k", "mc-24h"]
            .iter()
            .map(|n| by_name(n).name())
            .collect();
        assert_eq!(names, vec!["mc-100", "mc-100k", "mc-24h"]);
    }

    #[test]
    fn table4_list_matches_paper_rows() {
        // paper Table 4 lists: SubStrat, IG-KM, MAB, SubStrat-NF (flag),
        // IG-Rand, KM, MC-100K, MC-100 -> 7 subset strategies here
        assert_eq!(table4_strategies().len(), 7);
    }
}
