//! Category A — Monte-Carlo search (paper §4.2): draw random DSTs under
//! a budget, keep the one with the smallest measure-preservation loss.
//! Three paper instances: MC-100, MC-100K, and MC-24H (time-budgeted; we
//! scale the 24h budget to 20x Gen-DST's wall-clock on the same input,
//! preserving the paper's point that even a huge random budget loses —
//! see DESIGN.md §5).

use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::gendst::ops::random_candidate;
use crate::gendst::{fitness::FitnessBackend, fitness::FitnessEval, Dst, GenDstConfig};
use crate::util::rng::Rng;
use crate::util::timer::{Budget, Stopwatch};
use std::time::Duration;

pub struct MonteCarlo {
    pub max_evals: usize,
    /// if set, run for `mult x` the wall-clock Gen-DST takes on this input
    /// (the MC-24H stand-in)
    pub time_mult_of_gendst: Option<f64>,
}

impl SubsetStrategy for MonteCarlo {
    fn name(&self) -> &'static str {
        "mc"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let mut eval =
            FitnessEval::new(ctx.frame, ctx.codes, ctx.measure, FitnessBackend::NaiveNative);

        let mut budget = match self.time_mult_of_gendst {
            Some(mult) => {
                // estimate Gen-DST's cost on this input: one short probe run
                let probe = Stopwatch::start();
                let cfg = GenDstConfig {
                    generations: 2,
                    population: 20,
                    seed: ctx.seed,
                    ..Default::default()
                };
                let _ = crate::gendst::gen_dst(
                    ctx.frame, ctx.codes, ctx.measure, ctx.n, ctx.m, &cfg,
                );
                // full Gen-DST ~ 15x the probe (30 gens, 100 pop vs 2x20)
                let est_full = probe.elapsed().mul_f64(15.0);
                Budget::time(est_full.mul_f64(mult).max(Duration::from_millis(50)))
            }
            None => Budget::evals(self.max_evals),
        };
        budget.reset();

        let mut best: Option<(f64, Dst)> = None;
        while !budget.exhausted() {
            let c = random_candidate(ctx.frame, ctx.n, ctx.m, &mut rng);
            let loss = eval.loss(&c.rows, &c.cols);
            budget.consume();
            if best.as_ref().map_or(true, |(bl, _)| loss < *bl) {
                best = Some((
                    loss,
                    Dst {
                        rows: c.rows,
                        cols: c.cols,
                    },
                ));
            }
        }
        let (_, dst) = best.expect("MC budget allowed zero evaluations");
        StrategyOutcome {
            dst,
            elapsed_s: sw.elapsed_s(),
            evals: eval.evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::{registry, CodeMatrix};
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn more_budget_is_no_worse() {
        let f = registry::load("D2", 0.05, 3);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 9);
        let mut eval = FitnessEval::new(&f, &codes, &m, FitnessBackend::NaiveNative);

        let small = MonteCarlo { max_evals: 10, time_mult_of_gendst: None }.find(&ctx);
        let large = MonteCarlo { max_evals: 500, time_mult_of_gendst: None }.find(&ctx);
        let ls = eval.loss(&small.dst.rows, &small.dst.cols);
        let ll = eval.loss(&large.dst.rows, &large.dst.cols);
        assert!(ll <= ls + 1e-12, "500 evals worse than 10: {ll} vs {ls}");
        assert_eq!(large.evals, 500);
    }

    #[test]
    fn time_budget_variant_terminates() {
        let f = registry::load("D2", 0.03, 4);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 10);
        // tiny multiplier: just verifies the probe + budget path works
        let out = MonteCarlo { max_evals: usize::MAX, time_mult_of_gendst: Some(0.05) }.find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert!(out.evals > 0);
    }
}
