//! Category A — Monte-Carlo search (paper §4.2): draw random DSTs under
//! a budget, keep the one with the smallest measure-preservation loss.
//! Three paper instances: MC-100, MC-100K, and MC-24H (time-budgeted; we
//! scale the 24h budget to 20x Gen-DST's wall-clock on the same input,
//! preserving the paper's point that even a huge random budget loses —
//! see DESIGN.md §5).
//!
//! Timing contract (DESIGN.md §5.2): `StrategyOutcome.elapsed_s` covers
//! the random-search loop only. MC-24H's budget *estimation* (a short
//! anytime Gen-DST run through [`StopRule::TimeBudget`], at the cell's
//! own thread/island allowance — the same code path as the cell's real
//! Gen-DST run) is harness overhead that would never exist in the
//! paper's real 24h run, so it is reported as `setup_s` and excluded
//! from the timed window — previously it leaked into `elapsed_s` and
//! inflated `time_sub_s` for every mc-24h cell.

use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::gendst::ops::random_candidate;
use crate::gendst::pareto::Objective;
use crate::gendst::{fitness::FitnessBackend, fitness::FitnessEval, Dst, GenDstConfig, StopRule};
use crate::util::rng::Rng;
use crate::util::timer::{Budget, CpuTimer, Stopwatch};
use std::time::Duration;

/// Wall-clock window of the MC-24H budget probe's *generation loop*.
/// The engine's one-time setup (F(D) + the initial population fill)
/// and one guaranteed generation sit outside this bound — on a huge
/// frame the probe costs setup + one generation, the irreducible price
/// of a real throughput sample. All of it is reported as
/// `StrategyOutcome::setup_s` and excluded from every timed window.
const PROBE_WINDOW_S: f64 = 0.08;

pub struct MonteCarlo {
    /// which paper instance this is ("mc-100" | "mc-100k" | "mc-24h") —
    /// all three used to report the ambiguous name "mc"
    pub instance: &'static str,
    pub max_evals: usize,
    /// if set, run for `mult x` the wall-clock Gen-DST takes on this input
    /// (the MC-24H stand-in)
    pub time_mult_of_gendst: Option<f64>,
    /// thread allowance for the budget-estimation probe (0 = auto).
    /// The experiment runner passes the cell's inner allowance, so the
    /// probe's wall clock extrapolates to what the *real* Gen-DST cell
    /// costs under the same budget — a serial probe on a wide machine
    /// would overestimate Gen-DST's wall clock by the fill speedup and
    /// inflate the 20x budget by the same factor.
    pub probe_threads: usize,
    /// island count for the probe — the same value the cell's real
    /// Gen-DST run uses, for the same reason as `probe_threads`
    pub probe_islands: usize,
    /// objective vector for the probe — the NSGA-II path costs more
    /// per generation than the scalar path, so a scalar probe under a
    /// multi-objective cell would underestimate the 20x budget
    pub probe_objectives: Vec<Objective>,
}

impl MonteCarlo {
    /// Estimate the time budget for the MC-24H stand-in. Runs *before*
    /// the timed search window opens.
    ///
    /// Since PR 5 the probe IS the real engine: Gen-DST runs under a
    /// short [`StopRule::TimeBudget`] window at the cell's own
    /// thread/island allowance, and the full ψ-generation cost is
    /// extrapolated from the measured per-generation throughput. The
    /// old probe ran a 2-generation, 20-candidate mini-run and
    /// multiplied by 15 — a differently-shaped search through a
    /// differently-amortized code path (φ=100 fills parallelize and
    /// memoize very differently from φ=20 ones), so its estimate
    /// drifted from what the real Gen-DST cell actually costs.
    fn estimate_time_budget(&self, ctx: &StrategyContext, mult: f64) -> Duration {
        let base = GenDstConfig::default();
        let cfg = GenDstConfig {
            stop: StopRule::TimeBudget { seconds: PROBE_WINDOW_S },
            threads: self.probe_threads,
            islands: self.probe_islands,
            objectives: self.probe_objectives.clone(),
            seed: ctx.seed,
            ..base.clone()
        };
        let res = crate::gendst::gen_dst(ctx.frame, ctx.codes, ctx.measure, ctx.n, ctx.m, &cfg);
        // per-generation throughput EXCLUDING the one-time setup (F(D)
        // + initial fill): amortizing setup as per-generation cost
        // would inflate the extrapolated budget by up to ψ× on inputs
        // whose fill alone exceeds the probe window. The engine
        // guarantees ≥ 1 generation past the deadline, so the sample
        // is always real.
        let search_s = (res.elapsed_s - res.setup_s).max(0.0);
        let per_gen_s = search_s / res.generations_run.max(1) as f64;
        // deadline-stopped: extrapolate to the real cell's ψ cap;
        // converged inside the window: the probe WAS the full search
        // (the real cell, sharing seed and patience, stops there too)
        let est_gens = if res.timed_out {
            base.generations
        } else {
            res.generations_run.clamp(1, base.generations)
        };
        // the real cell pays setup once, then per-generation search
        let est_full = Duration::from_secs_f64(res.setup_s + per_gen_s * est_gens as f64);
        est_full.mul_f64(mult).max(Duration::from_millis(50))
    }
}

impl SubsetStrategy for MonteCarlo {
    fn name(&self) -> &'static str {
        self.instance
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let mut rng = Rng::new(ctx.seed);
        let mut eval =
            FitnessEval::new(ctx.frame, ctx.codes, ctx.measure, FitnessBackend::NaiveNative);

        // budget estimation happens outside the timed window; measured
        // on both clocks so the runner can subtract the one matching
        // its TimingMode (wall for Wall, CPU for CpuProxy)
        let (mut budget, setup_s, setup_cpu_s) = match self.time_mult_of_gendst {
            Some(mult) => {
                let setup_sw = Stopwatch::start();
                let setup_cpu = CpuTimer::start();
                let b = Budget::time(self.estimate_time_budget(ctx, mult));
                (b, setup_sw.elapsed_s(), setup_cpu.elapsed_s())
            }
            None => (Budget::evals(self.max_evals), 0.0, 0.0),
        };

        let sw = Stopwatch::start();
        budget.reset();
        let mut best: Option<(f64, Dst)> = None;
        // evaluate-then-check: even a zero budget gets one draw, so
        // `best` is always populated (the seed panicked on evals(0))
        loop {
            let c = random_candidate(ctx.frame, ctx.n, ctx.m, &mut rng);
            let loss = eval.loss(&c.rows, &c.cols);
            budget.consume();
            if best.as_ref().map_or(true, |(bl, _)| loss < *bl) {
                best = Some((
                    loss,
                    Dst {
                        rows: c.rows,
                        cols: c.cols,
                    },
                ));
            }
            if budget.exhausted() {
                break;
            }
        }
        let (_, dst) = best.expect("loop body ran at least once");
        StrategyOutcome {
            dst,
            elapsed_s: sw.elapsed_s(),
            setup_s,
            setup_cpu_s,
            evals: eval.evals,
            front: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::{registry, CodeMatrix};
    use crate::measures::entropy::EntropyMeasure;

    fn mc(max_evals: usize, mult: Option<f64>) -> MonteCarlo {
        MonteCarlo {
            instance: "mc-100",
            max_evals,
            time_mult_of_gendst: mult,
            probe_threads: 1,
            probe_islands: 1,
            probe_objectives: vec![Objective::Fidelity],
        }
    }

    #[test]
    fn more_budget_is_no_worse() {
        let f = registry::load("D2", 0.05, 3);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 9);
        let mut eval = FitnessEval::new(&f, &codes, &m, FitnessBackend::NaiveNative);

        let small = mc(10, None).find(&ctx);
        let large = mc(500, None).find(&ctx);
        let ls = eval.loss(&small.dst.rows, &small.dst.cols);
        let ll = eval.loss(&large.dst.rows, &large.dst.cols);
        assert!(ll <= ls + 1e-12, "500 evals worse than 10: {ll} vs {ls}");
        assert_eq!(large.evals, 500);
    }

    #[test]
    fn time_budget_variant_terminates() {
        let f = registry::load("D2", 0.03, 4);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 10);
        // tiny multiplier: just verifies the probe + budget path works
        let out = mc(usize::MAX, Some(0.05)).find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert!(out.evals > 0);
    }

    #[test]
    fn zero_eval_budget_still_evaluates_once() {
        // regression: Budget::evals(0) exhausted before the first draw,
        // leaving best = None and panicking on the unwrap
        let f = registry::load("D2", 0.03, 5);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 11);
        let out = mc(0, None).find(&ctx);
        assert_eq!(out.evals, 1, "zero budget must still guarantee one draw");
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
    }

    #[test]
    fn probe_run_is_excluded_from_the_timed_window() {
        // regression: the Gen-DST budget-estimation probe ran inside the
        // strategy's own Stopwatch, inflating elapsed_s (and with it
        // time_sub_s) for every mc-24h cell
        let f = registry::load("D2", 0.03, 6);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 12);
        let wall = Stopwatch::start();
        let out = mc(usize::MAX, Some(0.01)).find(&ctx);
        let total = wall.elapsed_s();
        assert!(out.setup_s > 0.0, "mc-24h must report its probe cost");
        // the serial probe's CPU time can never exceed its wall time
        // beyond clock quantization: tick-granular fallbacks (USER_HZ =
        // 100 ⇒ 10ms ticks) may round a tiny probe up by one tick, or
        // down to 0 — so the bound allows one full tick of slack
        assert!(
            out.setup_cpu_s <= out.setup_s + 0.011,
            "serial probe CPU {} > wall {}",
            out.setup_cpu_s,
            out.setup_s
        );
        // the two windows are disjoint sub-intervals of the outer wall
        // clock; before the fix elapsed_s covered probe + search, making
        // this sum exceed the outer measurement
        assert!(
            out.elapsed_s + out.setup_s <= total + 1e-4,
            "probe leaked into the timed window: search {} + setup {} > wall {}",
            out.elapsed_s,
            out.setup_s,
            total
        );
    }

    #[test]
    fn probe_runs_the_island_engine_at_the_cells_allowance() {
        // PR 5: the probe shares the island engine's code path — an
        // island-configured mc-24h cell probes with the same island
        // count and still produces a valid, positive budget window
        let f = registry::load("D2", 0.03, 8);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 14);
        let strat = MonteCarlo {
            instance: "mc-24h",
            max_evals: usize::MAX,
            time_mult_of_gendst: Some(0.01),
            probe_threads: 2,
            probe_islands: 2,
            probe_objectives: vec![Objective::Fidelity],
        };
        let out = strat.find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert!(out.setup_s > 0.0, "probe window must be reported");
        assert!(out.evals > 0);
    }

    #[test]
    fn eval_budgeted_instances_report_zero_setup() {
        let f = registry::load("D2", 0.03, 7);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 13);
        let out = mc(25, None).find(&ctx);
        assert_eq!(out.setup_s, 0.0);
        assert_eq!(out.setup_cpu_s, 0.0);
        assert_eq!(out.evals, 25);
    }
}
