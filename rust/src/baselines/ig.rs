//! Category E — information-gain feature selection baselines (paper
//! §4.2): columns are the top-(m-1) by IG w.r.t. the target; rows are
//! either uniform random (IG-Rand) or k-means representatives (IG-KM,
//! the paper's strongest baseline).

use crate::baselines::kmeans::kmeans_rows;
use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::data::binning::K_BINS;
use crate::data::CodeMatrix;
use crate::gendst::Dst;
use crate::measures::entropy::entropy_of_counts;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Information gain of a coded column w.r.t. labels:
/// IG = H(y) − Σ_v p(v) · H(y | x = v), computed over up to `max_rows`
/// strided rows (IG is a distribution statistic; striding preserves it).
pub fn info_gain(codes: &CodeMatrix, col: usize, labels: &[u32], n_classes: usize) -> f64 {
    const MAX_ROWS: usize = 100_000;
    let n = codes.n_rows;
    let stride = (n / MAX_ROWS).max(1);
    let column = codes.column(col);

    let mut joint = vec![0u32; K_BINS * n_classes];
    let mut label_counts = vec![0u32; n_classes];
    let mut bin_counts = [0u32; K_BINS];
    let mut total = 0usize;
    let mut r = 0usize;
    while r < n {
        let v = column[r] as usize;
        let c = labels[r] as usize;
        joint[v * n_classes + c] += 1;
        label_counts[c] += 1;
        bin_counts[v] += 1;
        total += 1;
        r += stride;
    }
    let h_y = entropy_of_counts(&label_counts, total);
    let mut h_cond = 0f64;
    for v in 0..K_BINS {
        if bin_counts[v] == 0 {
            continue;
        }
        let h = entropy_of_counts(
            &joint[v * n_classes..(v + 1) * n_classes],
            bin_counts[v] as usize,
        );
        h_cond += (bin_counts[v] as f64 / total as f64) * h;
    }
    (h_y - h_cond).max(0.0)
}

/// Top-(m-1) IG feature columns + the target column.
pub fn ig_columns(ctx: &StrategyContext) -> Vec<u32> {
    let labels = ctx.frame.labels();
    let n_classes = ctx.frame.n_classes();
    let mut scored: Vec<(u32, f64)> = ctx
        .frame
        .feature_indices()
        .into_iter()
        .map(|c| (c, info_gain(ctx.codes, c as usize, &labels, n_classes)))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut cols: Vec<u32> = scored
        .iter()
        .take(ctx.m - 1)
        .map(|&(c, _)| c)
        .collect();
    cols.push(ctx.frame.target as u32);
    cols
}

/// IG columns + uniform random rows.
pub struct IgRand;

impl SubsetStrategy for IgRand {
    fn name(&self) -> &'static str {
        "ig-rand"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let cols = ig_columns(ctx);
        let rows = rng.sample_distinct(ctx.frame.n_rows, ctx.n);
        StrategyOutcome {
            dst: Dst { rows, cols },
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: ctx.frame.n_cols() - 1,
            front: Vec::new(),
        }
    }
}

/// IG columns + k-means representative rows (paper's best baseline).
pub struct IgKm {
    pub lloyd_iters: usize,
}

impl Default for IgKm {
    fn default() -> Self {
        IgKm { lloyd_iters: 4 }
    }
}

impl SubsetStrategy for IgKm {
    fn name(&self) -> &'static str {
        "ig-km"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let cols = ig_columns(ctx);
        let rows = kmeans_rows(ctx.frame, ctx.n, self.lloyd_iters, &mut rng);
        StrategyOutcome {
            dst: Dst { rows, cols },
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: ctx.frame.n_cols() - 1,
            front: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::registry;
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn info_gain_ranks_informative_over_noise() {
        // D3 is the linear dataset: inf_num_* columns carry the label
        // signal, low_*/high_* columns do not
        let f = registry::load("D3", 0.08, 10);
        let codes = CodeMatrix::from_frame(&f);
        let labels = f.labels();
        let k = f.n_classes();
        // informative numeric columns are first (see synth.rs layout)
        let ig_informative = info_gain(&codes, 0, &labels, k);
        // the last feature columns are high-entropy noise
        let noise_col = f.n_cols() - 2;
        let ig_noise = info_gain(&codes, noise_col, &labels, k);
        assert!(
            ig_informative > ig_noise + 0.01,
            "IG failed to separate: inf={ig_informative} noise={ig_noise}"
        );
    }

    #[test]
    fn ig_columns_include_target_and_are_distinct() {
        let f = registry::load("D3", 0.05, 11);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 31);
        let cols = ig_columns(&ctx);
        assert_eq!(cols.len(), ctx.m);
        assert!(cols.contains(&(f.target as u32)));
        let mut c = cols.clone();
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), ctx.m);
    }

    #[test]
    fn ig_rand_and_ig_km_valid() {
        let f = registry::load("D3", 0.05, 12);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 32);
        for s in [&IgRand as &dyn SubsetStrategy, &IgKm::default()] {
            let out = s.find(&ctx);
            out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
            assert_eq!(out.dst.rows.len(), ctx.n, "{}", s.name());
        }
    }

    #[test]
    fn info_gain_zero_for_constant_column() {
        let f = registry::load("D3", 0.05, 13);
        let codes = CodeMatrix::from_frame(&f);
        let labels = f.labels();
        // find a low-noise (near-constant) column: named low_*
        let low_idx = f
            .columns
            .iter()
            .position(|c| c.name.starts_with("low_"))
            .expect("D3 has low-entropy distractors");
        let ig = info_gain(&codes, low_idx, &labels, f.n_classes());
        assert!(ig < 0.05, "near-constant IG {ig}");
    }
}
