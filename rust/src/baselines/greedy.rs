//! Category C — greedy selection (paper §4.2). The paper's unbounded
//! greedy scans exceeded its 24-hour cut-off and were dropped from
//! Table 4; we implement pool-capped versions (each greedy step picks the
//! best of `pool` random candidates instead of scanning all N/M) so the
//! algorithms are runnable, and keep them out of the Table-4 strategy
//! list exactly as the paper does.

use crate::baselines::{StrategyContext, StrategyOutcome, SubsetStrategy};
use crate::gendst::{fitness::FitnessBackend, fitness::FitnessEval, Dst};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Greedy-Seq: greedily grow the row set (loss measured with all
/// columns), then greedily grow the column set given those rows.
pub struct GreedySeq {
    pub pool: usize,
}

impl Default for GreedySeq {
    fn default() -> Self {
        GreedySeq { pool: 24 }
    }
}

fn greedy_grow<FLoss>(
    universe: usize,
    k: usize,
    pool: usize,
    rng: &mut Rng,
    pinned: &[u32],
    mut loss_of: FLoss,
) -> Vec<u32>
where
    FLoss: FnMut(&[u32]) -> f64,
{
    let mut chosen: Vec<u32> = pinned.to_vec();
    while chosen.len() < k {
        let mut best: Option<(f64, u32)> = None;
        for _ in 0..pool {
            let cand = rng.u64_below(universe as u64) as u32;
            if chosen.contains(&cand) {
                continue;
            }
            chosen.push(cand);
            let l = loss_of(&chosen);
            chosen.pop();
            if best.map_or(true, |(bl, _)| l < bl) {
                best = Some((l, cand));
            }
        }
        match best {
            Some((_, c)) => chosen.push(c),
            None => {
                // pool collisions only: fall back to any unchosen index
                for i in 0..universe as u32 {
                    if !chosen.contains(&i) {
                        chosen.push(i);
                        break;
                    }
                }
            }
        }
    }
    chosen
}

impl SubsetStrategy for GreedySeq {
    fn name(&self) -> &'static str {
        "greedy-seq"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let mut eval =
            FitnessEval::new(ctx.frame, ctx.codes, ctx.measure, FitnessBackend::NaiveNative);
        let all_cols: Vec<u32> = (0..ctx.frame.n_cols() as u32).collect();
        let target = ctx.frame.target as u32;

        // phase 1: rows, loss computed against all columns
        let rows = greedy_grow(ctx.frame.n_rows, ctx.n, self.pool, &mut rng, &[], |rows| {
            eval.loss(rows, &all_cols)
        });
        // phase 2: columns, loss computed with the chosen rows
        let cols = greedy_grow(
            ctx.frame.n_cols(),
            ctx.m,
            self.pool,
            &mut rng,
            &[target],
            |cols| eval.loss(&rows, cols),
        );
        StrategyOutcome {
            dst: Dst { rows, cols },
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: eval.evals,
            front: Vec::new(),
        }
    }
}

/// Greedy-Mult: alternately grow a row and a column each step (paper's
/// "row+columns" variant), with the same pool cap.
pub struct GreedyMult {
    pub pool: usize,
}

impl Default for GreedyMult {
    fn default() -> Self {
        GreedyMult { pool: 12 }
    }
}

impl SubsetStrategy for GreedyMult {
    fn name(&self) -> &'static str {
        "greedy-mult"
    }

    fn find(&self, ctx: &StrategyContext) -> StrategyOutcome {
        let sw = Stopwatch::start();
        let mut rng = Rng::new(ctx.seed);
        let mut eval =
            FitnessEval::new(ctx.frame, ctx.codes, ctx.measure, FitnessBackend::NaiveNative);
        let target = ctx.frame.target as u32;

        // seed with one random row + the target column
        let mut rows: Vec<u32> = vec![rng.u64_below(ctx.frame.n_rows as u64) as u32];
        let mut cols: Vec<u32> = vec![target];

        while rows.len() < ctx.n || cols.len() < ctx.m {
            if rows.len() < ctx.n {
                let mut best: Option<(f64, u32)> = None;
                for _ in 0..self.pool {
                    let cand = rng.u64_below(ctx.frame.n_rows as u64) as u32;
                    if rows.contains(&cand) {
                        continue;
                    }
                    rows.push(cand);
                    let l = eval.loss(&rows, &cols);
                    rows.pop();
                    if best.map_or(true, |(bl, _)| l < bl) {
                        best = Some((l, cand));
                    }
                }
                if let Some((_, c)) = best {
                    rows.push(c);
                }
            }
            if cols.len() < ctx.m {
                let mut best: Option<(f64, u32)> = None;
                for _ in 0..self.pool {
                    let cand = rng.u64_below(ctx.frame.n_cols() as u64) as u32;
                    if cols.contains(&cand) {
                        continue;
                    }
                    cols.push(cand);
                    let l = eval.loss(&rows, &cols);
                    cols.pop();
                    if best.map_or(true, |(bl, _)| l < bl) {
                        best = Some((l, cand));
                    }
                }
                if let Some((_, c)) = best {
                    cols.push(c);
                } else if cols.len() < ctx.m {
                    for i in 0..ctx.frame.n_cols() as u32 {
                        if !cols.contains(&i) {
                            cols.push(i);
                            break;
                        }
                    }
                }
            }
        }
        StrategyOutcome {
            dst: Dst { rows, cols },
            elapsed_s: sw.elapsed_s(),
            setup_s: 0.0,
            setup_cpu_s: 0.0,
            evals: eval.evals,
            front: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::test_ctx;
    use crate::data::{registry, CodeMatrix};
    use crate::measures::entropy::EntropyMeasure;

    #[test]
    fn greedy_seq_valid_output() {
        let f = registry::load("D2", 0.03, 6);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 21);
        let out = GreedySeq::default().find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(out.dst.rows.len(), ctx.n);
        assert_eq!(out.dst.cols.len(), ctx.m);
        assert!(out.evals > 0);
    }

    #[test]
    fn greedy_mult_valid_output() {
        let f = registry::load("D2", 0.03, 7);
        let codes = CodeMatrix::from_frame(&f);
        let m = EntropyMeasure;
        let ctx = test_ctx(&f, &codes, &m, 22);
        let out = GreedyMult::default().find(&ctx);
        out.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
        assert_eq!(out.dst.rows.len(), ctx.n);
        assert_eq!(out.dst.cols.len(), ctx.m);
    }

    #[test]
    fn greedy_grow_respects_pins() {
        let mut rng = Rng::new(8);
        let grown = greedy_grow(20, 5, 8, &mut rng, &[7], |_| 0.0);
        assert_eq!(grown[0], 7);
        assert_eq!(grown.len(), 5);
        let mut g = grown.clone();
        g.sort_unstable();
        g.dedup();
        assert_eq!(g.len(), 5);
    }
}
