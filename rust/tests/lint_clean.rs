//! The srclint pass (DESIGN.md §9) must be clean on this repository
//! itself: the linted tree includes the linter's own sources, so this
//! test is both the merge gate ("no findings at HEAD") and a live check
//! that the rules do not false-positive on real code.

use substrat::analysis::{collect_files, repo_root_from, run_lint, Finding, DEFAULT_PATHS};

#[test]
fn repo_sources_lint_clean() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = repo_root_from(manifest).expect("repo root above CARGO_MANIFEST_DIR");
    let paths: Vec<String> = DEFAULT_PATHS.iter().map(|s| s.to_string()).collect();
    let files = collect_files(&root, &paths).expect("collect repo sources");
    assert!(
        files.len() > 20,
        "expected a real tree, collected only {} file(s)",
        files.len()
    );
    assert!(
        files.iter().any(|(p, _)| p == "rust/src/analysis/mod.rs"),
        "the linter must lint itself"
    );
    let refs: Vec<(&str, &str)> = files
        .iter()
        .map(|(p, s)| (p.as_str(), s.as_str()))
        .collect();
    let findings = run_lint(&refs);
    assert!(
        findings.is_empty(),
        "lint must be clean at HEAD; got {} finding(s):\n{}",
        findings.len(),
        findings
            .iter()
            .map(Finding::text)
            .collect::<Vec<_>>()
            .join("\n")
    );
}
