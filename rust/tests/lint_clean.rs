//! The srclint pass (DESIGN.md §9, §11, §12) must be clean on this
//! repository itself: the linted tree includes the linter's own
//! sources, so this test is both the merge gate ("no findings at
//! HEAD") and a live check that the rules — the compile-review tier,
//! the discipline tier, the sigcheck signature tier, and the typeflow
//! dataflow tier — do not false-positive on real code. A second test
//! drives the `--json` surface: findings produced by the shared
//! fixture battery must round-trip through `util::json` and pass the
//! record schema check.

use std::collections::BTreeSet;

use substrat::analysis::sigcheck::{parse_manifest, MANIFEST_TEXT};
use substrat::analysis::{
    collect_files, repo_root_from, run_lint, run_lint_tiers, validate_finding_record, Finding,
    DEFAULT_PATHS,
};
use substrat::util::json;

fn repo_files() -> Vec<(String, String)> {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = repo_root_from(manifest).expect("repo root above CARGO_MANIFEST_DIR");
    let paths: Vec<String> = DEFAULT_PATHS.iter().map(|s| s.to_string()).collect();
    collect_files(&root, &paths).expect("collect repo sources")
}

#[test]
fn repo_sources_lint_clean() {
    let files = repo_files();
    assert!(
        files.len() > 20,
        "expected a real tree, collected only {} file(s)",
        files.len()
    );
    assert!(
        files.iter().any(|(p, _)| p == "rust/src/analysis/mod.rs"),
        "the linter must lint itself"
    );
    let refs: Vec<(&str, &str)> = files
        .iter()
        .map(|(p, s)| (p.as_str(), s.as_str()))
        .collect();
    let findings = run_lint(&refs);
    assert!(
        findings.is_empty(),
        "lint must be clean at HEAD; got {} finding(s):\n{}",
        findings.len(),
        findings
            .iter()
            .map(Finding::text)
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The typeflow tier alone, over the real tree: move/borrow dataflow
/// and local type inference must not false-positive anywhere in the
/// production sources (DESIGN.md §12's bail-out contract in action).
#[test]
fn repo_sources_clean_under_typeflow_tier_alone() {
    let files = repo_files();
    let refs: Vec<(&str, &str)> = files
        .iter()
        .map(|(p, s)| (p.as_str(), s.as_str()))
        .collect();
    let tiers: BTreeSet<String> = ["typeflow".to_string()].into_iter().collect();
    let findings = run_lint_tiers(&refs, Some(&tiers));
    assert!(
        findings.is_empty(),
        "typeflow tier must be clean at HEAD; got {} finding(s):\n{}",
        findings.len(),
        findings
            .iter()
            .map(Finding::text)
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// Every finding the engine can produce — including the sigcheck tier,
/// exercised here via the `want fire` cases of the shared fixture
/// manifest — must serialize to a `--json` line that parses back and
/// passes the journal record schema check.
#[test]
fn fixture_findings_round_trip_through_json() {
    let manifest = parse_manifest(MANIFEST_TEXT);
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut checked = 0usize;
    for case in manifest.cases.iter().filter(|c| c.want_fire) {
        let refs: Vec<(&str, &str)> = case
            .files
            .iter()
            .map(|(p, s)| (p.as_str(), s.as_str()))
            .collect();
        for f in run_lint(&refs) {
            let line = json::obj_to_line(&f.record());
            let parsed = json::parse_line(&line)
                .unwrap_or_else(|| panic!("{}: finding line must parse: {line}", case.name));
            validate_finding_record(&parsed)
                .unwrap_or_else(|e| panic!("{}: {}: {e}", case.name, f.text()));
            seen.insert(f.rule);
            checked += 1;
        }
    }
    assert!(checked > 0, "fire cases must produce findings");
    for rule in [
        "call-arity",
        "struct-fields",
        "enum-variant",
        "pub-sig-drift",
        "use-after-move",
        "double-mut-borrow",
        "must-use-result",
        "closure-capture-sync",
        "type-mismatch-lite",
    ] {
        assert!(seen.contains(rule), "round-tripped a {rule} finding");
    }
}
