//! The old CI shell smoke ("run `exp table4` twice, grep for 8/8 cells
//! resumed") promoted to a real integration test: the Table-4 grid runs
//! twice against the same journal, the second pass must serve every
//! cell from the journal, and the resumed records must be bit-equal to
//! the first run's (DESIGN.md §5.2).

use substrat::automl::SearcherKind;
use substrat::experiments::runner::Runner;
use substrat::experiments::{table4, ExpConfig};

#[test]
fn table4_rerun_resumes_every_cell_from_the_journal() {
    let cfg = ExpConfig {
        scale: 0.02,
        min_rows: 1_200,
        max_rows: 2_000,
        reps: 1,
        full_evals: 3,
        searchers: vec![SearcherKind::Random],
        datasets: vec!["D2".into()],
        threads: 2,
        batch: 2,
        out_dir: std::env::temp_dir().join("substrat_resume_it"),
        ..Default::default()
    };
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    let cells = table4::cells(&cfg);
    assert_eq!(cells.len(), 8, "one cell per Table-4 strategy");

    let first = Runner::new(&cfg).run(&cells);
    assert_eq!(first.len(), 8);
    assert!(
        first.iter().all(|o| !o.resumed),
        "a fresh journal must re-run everything"
    );
    let journal = cfg.out_dir.join("cells.jsonl");
    let journal_len = std::fs::metadata(&journal).map(|m| m.len()).unwrap_or(0);
    assert!(journal_len > 0, "journal missing or empty at {}", journal.display());

    let second = Runner::new(&cfg).run(&cells);
    assert_eq!(second.len(), 8);
    let resumed = second.iter().filter(|o| o.resumed).count();
    assert_eq!(resumed, 8, "expected 8/8 cells resumed, got {resumed}/8");
    // outcomes come back in input-cell order, so pairwise compare: a
    // journal round-trip must preserve every record bit-exactly
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.record.strategy, b.record.strategy);
        assert_eq!(a.record.dataset, b.record.dataset);
        assert_eq!(a.record.final_desc, b.record.final_desc, "{}", a.record.strategy);
        assert_eq!(
            a.record.acc_sub.to_bits(),
            b.record.acc_sub.to_bits(),
            "{}: resumed accuracy must be bit-equal",
            a.record.strategy
        );
        assert_eq!(a.record.acc_full.to_bits(), b.record.acc_full.to_bits());
        assert_eq!(a.record.time_full_s.to_bits(), b.record.time_full_s.to_bits());
        assert_eq!(a.record.time_sub_s.to_bits(), b.record.time_sub_s.to_bits());
    }
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
}
