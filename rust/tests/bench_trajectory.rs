//! Integration tests for the bench-trajectory subsystem (DESIGN.md
//! §5.4): every record a run emits validates against the documented
//! schema, `BENCH_<n>.json` numbering is monotone and never clobbers an
//! earlier run, a dry run is byte-deterministic modulo timestamps, and
//! a real (tiny) cell suite measures positive times.

use std::path::{Path, PathBuf};

use substrat::automl::SearcherKind;
use substrat::experiments::bench::{self, BenchConfig};
use substrat::experiments::ExpConfig;
use substrat::util::json::{self, Json};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn read_records(path: &Path) -> Vec<Vec<(String, Json)>> {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse_line(l).unwrap_or_else(|| panic!("unparseable line: {l}")))
        .collect()
}

fn dry_cfg(out_dir: PathBuf, suites: &str) -> BenchConfig {
    let mut exp = bench::quick_exp_config();
    exp.out_dir = out_dir;
    BenchConfig {
        suites: bench::resolve_suite_names(suites)
            .iter()
            .map(|s| s.to_string())
            .collect(),
        dry_run: true,
        exp,
    }
}

#[test]
fn dry_run_emits_schema_valid_records_for_every_suite() {
    let dir = tmp("substrat_bench_dry_all");
    let out = bench::run(&dry_cfg(dir.clone(), "all"));
    assert_eq!(out.run_no, 1);
    assert!(out.path.ends_with("BENCH_1.json"), "{}", out.path.display());
    let records = read_records(&out.path);
    assert_eq!(records.len(), out.records);

    // exactly one header, first in the file, carrying the schema tag
    let kinds: Vec<&str> = records
        .iter()
        .map(|r| json::get(r, "record").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(kinds[0], "header");
    assert_eq!(kinds.iter().filter(|k| **k == "header").count(), 1);
    assert_eq!(json::get(&records[0], "schema").unwrap().as_str(), Some("bench-v1"));

    for rec in &records {
        bench::validate_record(rec).unwrap_or_else(|e| panic!("invalid record ({e}): {rec:?}"));
        assert_eq!(json::get(rec, "dry"), Some(&Json::Bool(true)));
    }
    // every resolved suite contributed at least one record
    for suite in bench::resolve_suite_names("all") {
        assert!(
            records
                .iter()
                .any(|r| json::get(r, "suite").and_then(Json::as_str) == Some(suite)),
            "suite {suite} missing from the trajectory"
        );
    }
    // dry cell records carry real coordinates + fingerprints with stub
    // (zero) measurements
    let cell = records
        .iter()
        .find(|r| json::get(r, "record").unwrap().as_str() == Some("cell"))
        .expect("no cell records in an all-suites dry run");
    assert_eq!(json::get(cell, "cell").unwrap().as_str().unwrap().len(), 32);
    assert!(json::get(cell, "src").unwrap().as_str().unwrap().starts_with("table2:"));
    assert_eq!(json::get(cell, "time_full_s").unwrap().as_f64(), Some(0.0));
    assert_eq!(json::get(cell, "time_sub_s").unwrap().as_f64(), Some(0.0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_numbers_are_monotone_and_never_clobber() {
    let dir = tmp("substrat_bench_numbering_it");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("BENCH_3.json"), "sentinel").unwrap();
    std::fs::write(dir.join("BENCH_xyz.json"), "ignored").unwrap();
    let out = bench::run(&dry_cfg(dir.clone(), "table4"));
    assert_eq!(out.run_no, 4, "next number after an existing BENCH_3");
    assert!(out.path.ends_with("BENCH_4.json"));
    assert_eq!(
        std::fs::read_to_string(dir.join("BENCH_3.json")).unwrap(),
        "sentinel",
        "existing runs are never clobbered"
    );
    let again = bench::run(&dry_cfg(dir.clone(), "table4"));
    assert_eq!(again.run_no, 5, "numbering keeps climbing");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dry_runs_are_identical_modulo_timestamps() {
    let dir = tmp("substrat_bench_determinism");
    let a = bench::run(&dry_cfg(dir.clone(), "all"));
    let b = bench::run(&dry_cfg(dir.clone(), "all"));
    // strip the one timestamp field and re-serialize through the same
    // writer; everything that remains must be byte-identical
    let canon = |path: &Path| -> Vec<String> {
        read_records(path)
            .into_iter()
            .map(|rec| {
                let pairs: Vec<(&str, Json)> = rec
                    .iter()
                    .filter(|(k, _)| k != "unix_time")
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect();
                json::obj_to_line(&pairs)
            })
            .collect()
    };
    assert_eq!(canon(&a.path), canon(&b.path), "dry trajectory must be deterministic");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn real_table4_suite_measures_positive_times() {
    let dir = tmp("substrat_bench_real_table4");
    let exp = ExpConfig {
        scale: 0.02,
        min_rows: 1_200,
        max_rows: 2_000,
        reps: 1,
        full_evals: 3,
        searchers: vec![SearcherKind::Random],
        datasets: vec!["D2".into()],
        threads: 2,
        batch: 2,
        out_dir: dir.clone(),
        ..Default::default()
    };
    let bcfg = BenchConfig {
        suites: vec!["table4".into()],
        dry_run: false,
        exp,
    };
    let out = bench::run(&bcfg);
    let records = read_records(&out.path);
    for rec in &records {
        bench::validate_record(rec).unwrap_or_else(|e| panic!("invalid record ({e}): {rec:?}"));
    }
    let cells: Vec<_> = records
        .iter()
        .filter(|r| json::get(r, "record").unwrap().as_str() == Some("cell"))
        .collect();
    assert_eq!(cells.len(), 8, "one cell per Table-4 strategy");
    for c in &cells {
        assert_eq!(json::get(c, "dry"), Some(&Json::Bool(false)));
        assert!(json::get(c, "time_full_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(json::get(c, "time_sub_s").unwrap().as_f64().unwrap() > 0.0);
    }
    let suite = records
        .iter()
        .find(|r| json::get(r, "record").unwrap().as_str() == Some("suite"))
        .expect("no suite summary record");
    assert_eq!(json::get(suite, "cells").unwrap().as_f64(), Some(8.0));
    assert!(json::get(suite, "wall_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(json::get(suite, "cpu_s").unwrap().as_f64().unwrap() >= 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}
