//! Integration tests across the three layers: artifact contracts on the
//! runtime (native interpreter offline; PJRT when the `xla` crate and
//! compiled artifacts are present) vs the native substrate, Gen-DST on
//! both fitness backends, and the full SubStrat flow. The manifest
//! shape cross-check skips gracefully when `make artifacts` was never
//! run.

use substrat::automl::{run_automl, AutoMlConfig, SearcherKind};
use substrat::baselines;
use substrat::data::{registry, CodeMatrix};
use substrat::gendst::fitness::{FitnessBackend, FitnessEval};
use substrat::gendst::{gen_dst, GenDstConfig};
use substrat::measures::entropy::{subset_entropy, EntropyMeasure};
use substrat::runtime::entropy_exec::EntropyExec;
use substrat::runtime::models_exec::{class_mask, pack_batch, LogregParams, ModelsExec};
use substrat::runtime::{self, shapes};
use substrat::substrat::{run_substrat, SubStratConfig};
use substrat::util::rng::Rng;

#[test]
fn all_artifacts_load_and_compile() {
    let rt = runtime::thread_current().expect("runtime");
    for name in [
        "entropy_subset",
        "entropy_batch",
        "entropy_columns",
        "logreg_train_step",
        "logreg_predict",
        "mlp_train_step",
        "mlp_predict",
        "kmeans_step",
    ] {
        rt.load(name)
            .unwrap_or_else(|e| panic!("artifact {name} failed: {e:?}"));
    }
}

#[test]
fn manifest_matches_shape_constants() {
    let dir = runtime::XlaRuntime::default_dir();
    let Ok(manifest) = std::fs::read_to_string(dir.join("manifest.txt")) else {
        // artifacts were never built in this environment (run `make
        // artifacts`); the native interpreter does not need them, so the
        // shape cross-check is vacuous — skip gracefully (see ci.yml)
        eprintln!("skipping manifest_matches_shape_constants: no artifacts/manifest.txt");
        return;
    };
    let header = manifest.lines().next().unwrap();
    assert!(header.contains(&format!("{}x{}", shapes::N_PAD, shapes::M_PAD)), "{header}");
    assert!(header.contains(&format!("K={}", shapes::K_BINS)), "{header}");
    assert!(header.contains(&format!("B={}", shapes::B_BATCH)), "{header}");
    assert!(header.contains(&format!("F={}", shapes::F_PAD)), "{header}");
    assert!(header.contains(&format!("C={}", shapes::C_PAD)), "{header}");
    assert!(manifest.contains(&format!(
        "entropy_subset: i32[{},{}]",
        shapes::N_PAD,
        shapes::M_PAD
    )));
}

#[test]
fn xla_entropy_matches_native_across_random_subsets() {
    let f = registry::load("D3", 0.08, 3);
    let codes = CodeMatrix::from_frame(&f);
    let rt = runtime::thread_current().unwrap();
    let mut exec = EntropyExec::new(&rt);
    let mut rng = Rng::new(5);
    for _ in 0..12 {
        let n = 2 + rng.usize_below(500);
        let m = 2 + rng.usize_below(f.n_cols() - 2);
        let rows = rng.sample_distinct(f.n_rows, n);
        let mut cols = rng.sample_distinct(f.n_cols(), m);
        if !cols.contains(&(f.target as u32)) {
            cols[0] = f.target as u32;
        }
        let native = subset_entropy(&codes, &rows, &cols);
        let xla = exec.subset_entropy(&codes, &rows, &cols).unwrap();
        assert!(
            (native - xla).abs() < 1e-4,
            "mismatch at n={n} m={m}: {native} vs {xla}"
        );
    }
}

#[test]
fn xla_batch_matches_singles() {
    let f = registry::load("D2", 0.05, 4);
    let codes = CodeMatrix::from_frame(&f);
    let rt = runtime::thread_current().unwrap();
    let mut exec = EntropyExec::new(&rt);
    let mut rng = Rng::new(6);
    // more subsets than one batch slot set to exercise chunking
    let subsets: Vec<(Vec<u32>, Vec<u32>)> = (0..(shapes::B_BATCH + 3))
        .map(|_| {
            let rows = rng.sample_distinct(f.n_rows, 50);
            let mut cols = rng.sample_distinct(f.n_cols(), 3);
            if !cols.contains(&(f.target as u32)) {
                cols[0] = f.target as u32;
            }
            (rows, cols)
        })
        .collect();
    let refs: Vec<(&[u32], &[u32])> = subsets
        .iter()
        .map(|(r, c)| (r.as_slice(), c.as_slice()))
        .collect();
    let batch = exec.batch_entropy(&codes, &refs).unwrap();
    assert_eq!(batch.len(), subsets.len());
    for (i, (rows, cols)) in subsets.iter().enumerate() {
        let single = exec.subset_entropy(&codes, rows, cols).unwrap();
        assert!(
            (batch[i] - single).abs() < 1e-5,
            "slot {i}: {} vs {single}",
            batch[i]
        );
    }
}

#[test]
fn gendst_xla_backend_agrees_with_native() {
    let f = registry::load("D2", 0.04, 7);
    let codes = CodeMatrix::from_frame(&f);
    let mk = |backend| GenDstConfig {
        generations: 5,
        population: 20,
        backend,
        seed: 11,
        ..Default::default()
    };
    let native = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mk(FitnessBackend::NaiveNative));
    let inc = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mk(FitnessBackend::Incremental));
    let xla = gen_dst(&f, &codes, &EntropyMeasure, 30, 3, &mk(FitnessBackend::Xla));
    // the two native engines must agree exactly (bit-identical losses)
    assert_eq!(native.dst, inc.dst, "incremental engine diverged");
    assert!((native.loss - inc.loss).abs() <= 1e-9);
    // identical seeds and near-identical numerics (f32 vs f64) must yield
    // equally good subsets; allow tiny slack for tie-breaking divergence
    assert!(
        (native.loss - xla.loss).abs() < 5e-3,
        "backend divergence: native {} vs xla {}",
        native.loss,
        xla.loss
    );
    xla.dst.validate(f.n_rows, f.n_cols(), f.target).unwrap();
}

#[test]
fn xla_fitness_eval_matches_native_losses() {
    let f = registry::load("D2", 0.04, 8);
    let codes = CodeMatrix::from_frame(&f);
    let mut nat = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::NaiveNative);
    let mut xla = FitnessEval::new(&f, &codes, &EntropyMeasure, FitnessBackend::Xla);
    let mut rng = Rng::new(9);
    for _ in 0..6 {
        let rows = rng.sample_distinct(f.n_rows, 40);
        let mut cols = rng.sample_distinct(f.n_cols(), 3);
        if !cols.contains(&(f.target as u32)) {
            cols[0] = f.target as u32;
        }
        let a = nat.loss(&rows, &cols);
        let b = xla.loss(&rows, &cols);
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn logreg_artifact_step_decreases_loss() {
    let rt = runtime::thread_current().unwrap();
    let exec = ModelsExec::new(&rt);
    let mut rng = Rng::new(10);
    // blobs in padded space
    let mut x = substrat::data::Matrix::zeros(shapes::BATCH, 8);
    let mut y = vec![0u32; shapes::BATCH];
    for i in 0..shapes::BATCH {
        let c = i % 2;
        y[i] = c as u32;
        for j in 0..8 {
            x.set(i, j, ((c as f64 * 4.0 - 2.0) + rng.normal()) as f32);
        }
    }
    let idx: Vec<usize> = (0..shapes::BATCH).collect();
    let batch = pack_batch(&x, &y, &idx).unwrap();
    let cmask = class_mask(2);
    let mut params = LogregParams::zeros();
    let first = exec
        .logreg_step(&mut params, &batch, &cmask, 0.5, 0.0)
        .unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = exec
            .logreg_step(&mut params, &batch, &cmask, 0.5, 0.0)
            .unwrap();
    }
    assert!(
        last < first * 0.5,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn substrat_flow_beats_full_automl_on_time() {
    let f = registry::load("D3", 0.3, 12); // 3000 x 18
    let codes = CodeMatrix::from_frame(&f);
    let automl_cfg = AutoMlConfig::new(SearcherKind::Smbo, 8, 5);

    let sw = substrat::util::timer::Stopwatch::start();
    let full = run_automl(&f, &automl_cfg);
    let t_full = sw.elapsed_s();

    let strategy = baselines::by_name("gendst");
    let run = run_substrat(
        &f,
        &codes,
        &EntropyMeasure,
        strategy.as_ref(),
        &automl_cfg,
        &SubStratConfig::default(),
    );
    assert!(
        run.total_time_s < t_full,
        "substrat {} not faster than full {}",
        run.total_time_s,
        t_full
    );
    assert!(full.best_cv > 0.5);
    assert!(run.automl_sub.best_cv > 0.0);
}

/// The committed real-CSV fixture (mixed types, quoted separators,
/// missing values; see tests/fixtures/).
fn fixture_path() -> String {
    format!("{}/tests/fixtures/bank_mini.csv", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn csv_fixture_ingests_with_streaming_binning_bit_identical() {
    use substrat::data::DataSource;
    let src = DataSource::parse(&fixture_path());
    assert!(src.is_csv());
    let ds = src.load_csv_dataset();
    assert_eq!(ds.frame.shape(), (320, 5));
    assert_eq!(ds.frame.n_classes(), 2);
    // age/income/score numeric, city/label categorical
    let cats: Vec<bool> = ds.frame.columns.iter().map(|c| c.categorical).collect();
    assert_eq!(cats, vec![false, false, true, false, true]);
    assert!(ds.summary.columns[1].missing > 0, "fixture must exercise missing values");
    // the streaming-binned codes are bit-identical to the in-memory path
    let reference = CodeMatrix::from_frame(&ds.frame);
    for c in 0..ds.frame.n_cols() {
        assert_eq!(ds.codes.column(c), reference.column(c), "column {c}");
    }
    assert_eq!(ds.codes.cardinality, reference.cardinality);
}

#[test]
fn substrat_end_to_end_on_real_csv_fixture() {
    // the acceptance flow: the fixture runs the identical harness a
    // registry symbol does — prepare (via DataSource), Full-AutoML
    // reference, SubStrat cell, journaled resume
    use substrat::experiments::runner::{strategy_grid, Runner};
    use substrat::experiments::ExpConfig;
    let cfg = ExpConfig {
        reps: 1,
        full_evals: 4,
        searchers: vec![SearcherKind::Random],
        datasets: vec![fixture_path()],
        threads: 1,
        out_dir: std::env::temp_dir().join("substrat_it_csv"),
        ..Default::default()
    };
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    let cells = strategy_grid(&cfg, &["gendst"]);
    let out = Runner::new(&cfg).run(&cells);
    assert_eq!(out.len(), 1);
    let rec = &out[0].record;
    assert!(rec.acc_full > 0.55, "full AutoML below chance on the fixture: {}", rec.acc_full);
    assert!(rec.acc_sub > 0.55, "SubStrat below chance on the fixture: {}", rec.acc_sub);
    assert!(rec.time_full_s > 0.0 && rec.time_sub_s > 0.0);
    // the journal resumes the cell, keyed by the file's content hash
    let again = Runner::new(&cfg).run(&cells);
    assert!(again[0].resumed, "csv cell did not resume from the journal");
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
}

#[test]
fn every_table4_strategy_completes_one_cell() {
    use substrat::experiments::{prepare, run_full, run_strategy, ExpConfig};
    let cfg = ExpConfig {
        scale: 0.02,
        min_rows: 1_200,
        max_rows: 2_000,
        reps: 1,
        full_evals: 4,
        searchers: vec![SearcherKind::Random],
        datasets: vec!["D2".into()],
        threads: 1,
        out_dir: std::env::temp_dir().join("substrat_it"),
        ..Default::default()
    };
    let prep = prepare("D2", &cfg, 0);
    let full = run_full(&prep, SearcherKind::Random, &cfg, 0);
    for s in substrat::experiments::table4_strategy_names() {
        let rec = run_strategy(&prep, "D2", s, SearcherKind::Random, &full, &cfg, 0, None);
        assert!(rec.acc_sub > 0.0, "{s} produced zero accuracy");
        assert!(rec.time_sub_s > 0.0, "{s} not timed");
    }
}
