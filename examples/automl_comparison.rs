//! Compare the two AutoML searchers (SMBO ~ Auto-Sklearn, GP ~ TPOT) and
//! random search head-to-head on one dataset — the substrate the paper
//! treats as the black box `A`.
//!
//!   cargo run --release --example automl_comparison [-- --dataset D6 --scale 0.05 --evals 16]

use substrat::automl::{run_automl, AutoMlConfig, SearcherKind};
use substrat::data::registry;
use substrat::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let symbol = args.str_or("dataset", "D6");
    let scale = args.f64_or("scale", 0.05);
    let evals = args.usize_or("evals", 16);
    let frame = registry::load(&symbol, scale, 7);
    println!("dataset {symbol} {:?} ({} classes)", frame.shape(), frame.n_classes());
    println!("{:<8} {:<34} {:>8} {:>9}", "searcher", "best pipeline", "cv acc", "time");
    for searcher in [SearcherKind::Smbo, SearcherKind::Gp, SearcherKind::Random] {
        let cfg = AutoMlConfig::new(searcher, evals, 7);
        let res = run_automl(&frame, &cfg);
        println!(
            "{:<8} {:<34} {:>8.4} {:>8.2}s",
            searcher.name(), res.best.describe(), res.best_cv, res.elapsed_s
        );
    }
}
