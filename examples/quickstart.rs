//! Quickstart: the SubStrat public API in ~30 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Loads a small registry dataset, runs Full-AutoML, then SubStrat, and
//! prints the paper's two metrics (time-reduction, relative-accuracy).

use substrat::automl::{eval::fit_on_frame, run_automl, AutoMlConfig, SearcherKind};
use substrat::baselines;
use substrat::data::{registry, split, CodeMatrix};
use substrat::experiments::{charged_time_s, TimingMode};
use substrat::measures::entropy::EntropyMeasure;
use substrat::substrat::{run_substrat, SubStratConfig};
use substrat::util::rng::Rng;
use substrat::util::timer::Stopwatch;

fn main() {
    // 1. a dataset (D3 "car insurance" at 10% scale) + holdout split
    let frame = registry::load("D3", 0.1, 42);
    let mut rng = Rng::new(42);
    let (train, test) = split::train_test_split(&frame, 0.25, &mut rng);
    let codes = CodeMatrix::from_frame(&train);
    println!("dataset {} -> train {:?} / test {:?}", frame.name, train.shape(), test.shape());

    // 2. Full-AutoML reference: A(D, y) -> M*
    let automl = AutoMlConfig::new(SearcherKind::Smbo, 12, 42);
    let sw = Stopwatch::start();
    let full = run_automl(&train, &automl);
    let t_full = sw.elapsed_s();
    let acc_full = fit_on_frame(&full.best, &train, &mut rng).accuracy_on(&test);
    println!("Full-AutoML: {} acc={acc_full:.4} time={t_full:.2}s", full.best.describe());

    // 3. SubStrat: Gen-DST subset -> AutoML on subset -> fine-tune
    let strategy = baselines::by_name("gendst");
    let run = run_substrat(
        &train, &codes, &EntropyMeasure, strategy.as_ref(), &automl,
        &SubStratConfig::default(),
    );
    let acc_sub = fit_on_frame(&run.final_config, &train, &mut rng).accuracy_on(&test);
    // total_time_s is raw; the paper window excludes strategy setup
    // overhead via the single subtraction site (gendst's setup is 0,
    // but e.g. mc-24h's budget probe is not)
    let t_sub = charged_time_s(run.total_time_s, &run.outcome, TimingMode::Wall);
    println!("SubStrat:    {} acc={acc_sub:.4} time={t_sub:.2}s", run.final_config.describe());

    // 4. the paper's metrics
    println!("time-reduction    = {:.1}%", 100.0 * (1.0 - t_sub / t_full));
    println!("relative-accuracy = {:.1}%", 100.0 * acc_sub / acc_full);
}
