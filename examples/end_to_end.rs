//! End-to-end validation driver (DESIGN.md experiment HL): the full
//! three-layer system on a realistic workload — the flight-review
//! dataset D1 — reporting the paper's headline metric.
//!
//! Pipeline exercised: synthetic D1 at --scale -> quantile binning ->
//! Gen-DST GA whose fitness is the dataset-entropy measure (native +
//! AOT Pallas kernel cross-checked) -> AutoML (SMBO + GP searchers, XLA
//! logreg/MLP train steps on PJRT + native trees/forest/kNN/NB) ->
//! restricted fine-tune -> holdout accuracy, versus the Full-AutoML
//! reference. Run is recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example end_to_end [-- --scale 0.05 --evals 16 --reps 2]
//!
//! Any real CSV runs through the identical harness (DESIGN.md §5.3):
//!
//!   cargo run --release --example end_to_end -- --data my.csv

use substrat::automl::SearcherKind;
use substrat::data::CodeMatrix;
use substrat::experiments::runner::{strategy_grid, Runner};
use substrat::experiments::{prepare, ExpConfig};
use substrat::runtime::{self, entropy_exec::EntropyExec};
use substrat::util::cli::Args;
use substrat::util::rng::Rng;
use substrat::util::stats;

fn main() {
    let args = Args::from_env();
    // --data <csv> routes a real file through the same harness; the
    // registry symbol path is the default (DataSource resolves both)
    let spec = args
        .str_opt("data")
        .map(str::to_string)
        .unwrap_or_else(|| args.str_or("dataset", "D1"));
    let cfg = ExpConfig {
        scale: args.f64_or("scale", 0.05),
        reps: args.usize_or("reps", 2),
        full_evals: args.usize_or("evals", 16),
        searchers: vec![SearcherKind::Smbo, SearcherKind::Gp],
        datasets: vec![spec],
        csv_target: args.str_opt("target").map(str::to_string),
        csv_header: args
            .str_opt("header")
            .map(substrat::data::infer::parse_header_flag),
        threads: args.usize_or("threads", 0),
        out_dir: std::path::PathBuf::from(args.str_or("out", "results/end_to_end")),
        ..Default::default()
    };
    let symbol = cfg.datasets[0].clone();
    std::fs::create_dir_all(&cfg.out_dir).ok();

    // layer check: XLA entropy kernel vs native on this dataset
    let probe = prepare(&symbol, &cfg, 0);
    let codes = CodeMatrix::from_frame(&probe.train);
    let rt = runtime::thread_current().expect("run `make artifacts` first");
    let mut exec = EntropyExec::new(&rt);
    let mut rng = Rng::new(1);
    let rows = rng.sample_distinct(probe.train.n_rows, 128);
    let cols: Vec<u32> = (0..probe.train.n_cols() as u32).collect();
    let native = substrat::measures::entropy::subset_entropy(&codes, &rows, &cols);
    let xla = exec.subset_entropy(&codes, &rows, &cols).expect("entropy artifact");
    println!(
        "[layers] entropy native={native:.6} pallas/pjrt={xla:.6} |diff|={:.1e}",
        (native - xla).abs()
    );
    assert!((native - xla).abs() < 1e-4);

    // the (searcher × rep) sweep goes through the shared cell scheduler:
    // Wall timing (serial cells, exclusive inner parallelism) and a
    // resumable journal under --out, so an interrupted run continues
    let cells = strategy_grid(&cfg, &["gendst"]);
    let mut trs = Vec::new();
    let mut ras = Vec::new();
    for o in Runner::new(&cfg).run(&cells) {
        let rec = &o.record;
        println!(
            "[{}/rep{}{}] full: acc={:.4} t={:.1}s  substrat: acc={:.4} t={:.1}s ({})  \
             -> TR={:.1}% RA={:.1}%",
            rec.searcher, rec.rep, if o.resumed { " journal" } else { "" },
            rec.acc_full, rec.time_full_s,
            rec.acc_sub, rec.time_sub_s, rec.final_desc,
            100.0 * rec.time_reduction(), 100.0 * rec.relative_accuracy()
        );
        trs.push(rec.time_reduction());
        ras.push(rec.relative_accuracy());
    }
    println!(
        "\nheadline ({symbol}, scale {}): time-reduction {:.1}% +- {:.1}%, \
         relative-accuracy {:.1}% +- {:.1}%",
        cfg.scale,
        100.0 * stats::mean(&trs), 100.0 * stats::std(&trs),
        100.0 * stats::mean(&ras), 100.0 * stats::std(&ras)
    );
    println!("(paper: 79% mean time reduction at ~98% relative accuracy)");
}
