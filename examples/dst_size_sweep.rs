//! Mini Figure-4/5 driver: sweep the DST size on one dataset and print
//! the accuracy/time trade-off curve — the paper's §4.5 analysis at
//! example scale.
//!
//!   cargo run --release --example dst_size_sweep [-- --dataset D3 --scale 0.05]

use substrat::automl::SearcherKind;
use substrat::experiments::fig4::{m_grid, n_grid};
use substrat::experiments::{prepare, run_full, run_strategy, ExpConfig};
use substrat::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cfg = ExpConfig {
        scale: args.f64_or("scale", 0.05),
        reps: 1,
        full_evals: args.usize_or("evals", 10),
        searchers: vec![SearcherKind::Smbo],
        datasets: vec![args.str_or("dataset", "D3")],
        threads: 1,
        ..Default::default()
    };
    let symbol = cfg.datasets[0].clone();
    let prep = prepare(&symbol, &cfg, 0);
    let full = run_full(&prep, SearcherKind::Smbo, &cfg, 0);
    println!(
        "{symbol} train {:?}, Full-AutoML acc={:.4} t={:.1}s",
        prep.train.shape(),
        full.test_acc,
        full.elapsed_s
    );
    let (_, m0) = substrat::gendst::default_dst_size(prep.train.n_rows, prep.train.n_cols());

    println!("\n-- n sweep (m=0.25M) --");
    println!("{:<12} {:>8} {:>10} {:>10}", "n", "rows", "rel_acc", "time_red");
    for (label, n) in n_grid(prep.train.n_rows) {
        let size = Some((n, m0));
        let rec = run_strategy(&prep, &symbol, "gendst", SearcherKind::Smbo, &full, &cfg, 0, size);
        println!(
            "{label:<12} {n:>8} {:>10.4} {:>10.4}",
            rec.relative_accuracy(),
            rec.time_reduction()
        );
    }
    let (n0, _) = substrat::gendst::default_dst_size(prep.train.n_rows, prep.train.n_cols());
    println!("\n-- m sweep (n=sqrtN) --");
    println!("{:<12} {:>8} {:>10} {:>10}", "m", "cols", "rel_acc", "time_red");
    for (label, m) in m_grid(prep.train.n_cols()) {
        let size = Some((n0, m));
        let rec = run_strategy(&prep, &symbol, "gendst", SearcherKind::Smbo, &full, &cfg, 0, size);
        println!(
            "{label:<12} {m:>8} {:>10.4} {:>10.4}",
            rec.relative_accuracy(),
            rec.time_reduction()
        );
    }
}
