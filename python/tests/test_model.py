"""L2 correctness: train steps learn, predictions are masked, the batched
entropy graph matches the scalar one, and every SPECS entry lowers to
parseable HLO text.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import shapes as S
from compile.aot import to_hlo_text
from compile.kernels.ref import dataset_entropy_ref
from compile.model import (SPECS, entropy_batch, entropy_subset,
                           kmeans_step, logreg_predict, logreg_train_epoch,
                           logreg_train_step, mlp_predict, mlp_train_epoch,
                           mlp_train_step)

jax.config.update("jax_platform_name", "cpu")


def _blob_problem(rng, n_cls=3, sep=4.0):
    """Linearly separable gaussian blobs in the padded feature space."""
    x = np.zeros((S.BATCH, S.F_PAD), dtype=np.float32)
    y = np.zeros(S.BATCH, dtype=np.int64)
    centers = rng.normal(0, sep, size=(n_cls, 8)).astype(np.float32)
    for i in range(S.BATCH):
        c = i % n_cls
        x[i, :8] = centers[c] + rng.normal(0, 1.0, 8)
        y[i] = c
    yoh = np.zeros((S.BATCH, S.C_PAD), dtype=np.float32)
    yoh[np.arange(S.BATCH), y] = 1.0
    smask = np.ones(S.BATCH, dtype=np.float32)
    cmask = np.zeros(S.C_PAD, dtype=np.float32)
    cmask[:n_cls] = 1.0
    return x, y, yoh, smask, cmask


class TestLogreg:
    def test_loss_decreases_and_learns(self):
        rng = np.random.default_rng(0)
        x, y, yoh, smask, cmask = _blob_problem(rng)
        w = np.zeros((S.F_PAD, S.C_PAD), dtype=np.float32)
        b = np.zeros(S.C_PAD, dtype=np.float32)
        losses = []
        step = jax.jit(logreg_train_step)
        for _ in range(60):
            w, b, loss = step(x, yoh, smask, cmask, w, b,
                              jnp.float32(0.5), jnp.float32(1e-4))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5
        (logits,) = logreg_predict(x, w, b, cmask)
        acc = float((np.argmax(np.asarray(logits), axis=1) == y).mean())
        assert acc > 0.9

    def test_padded_classes_never_predicted(self):
        rng = np.random.default_rng(1)
        x, y, yoh, smask, cmask = _blob_problem(rng, n_cls=3)
        w = rng.normal(0, 1, (S.F_PAD, S.C_PAD)).astype(np.float32)
        b = rng.normal(0, 1, S.C_PAD).astype(np.float32)
        (logits,) = logreg_predict(x, w, b, cmask)
        pred = np.argmax(np.asarray(logits), axis=1)
        assert (pred < 3).all()

    def test_sample_mask_freezes_masked_rows_influence(self):
        """Gradient with smask zeroing rows == gradient on those rows gone."""
        rng = np.random.default_rng(2)
        x, y, yoh, smask, cmask = _blob_problem(rng)
        smask2 = smask.copy()
        smask2[100:] = 0.0
        w = rng.normal(0, 0.1, (S.F_PAD, S.C_PAD)).astype(np.float32)
        b = np.zeros(S.C_PAD, dtype=np.float32)
        w1, b1, _ = logreg_train_step(x, yoh, smask2, cmask, w, b,
                                      jnp.float32(0.1), jnp.float32(0.0))
        x3 = x.copy()
        x3[100:] = 999.0  # garbage in masked rows must not matter
        w2, b2, _ = logreg_train_step(x3, yoh, smask2, cmask, w, b,
                                      jnp.float32(0.1), jnp.float32(0.0))
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)

    def test_l2_shrinks_weights(self):
        rng = np.random.default_rng(3)
        x, y, yoh, smask, cmask = _blob_problem(rng)
        w = rng.normal(0, 1, (S.F_PAD, S.C_PAD)).astype(np.float32)
        b = np.zeros(S.C_PAD, dtype=np.float32)
        w_hi, _, _ = logreg_train_step(x, yoh, smask, cmask, w, b,
                                       jnp.float32(0.1), jnp.float32(1.0))
        w_lo, _, _ = logreg_train_step(x, yoh, smask, cmask, w, b,
                                       jnp.float32(0.1), jnp.float32(0.0))
        assert float(jnp.sum(w_hi ** 2)) < float(jnp.sum(w_lo ** 2))


class TestMlp:
    def test_learns_xor_like(self):
        rng = np.random.default_rng(4)
        x = np.zeros((S.BATCH, S.F_PAD), dtype=np.float32)
        raw = rng.uniform(-1, 1, size=(S.BATCH, 2)).astype(np.float32)
        x[:, :2] = raw
        y = ((raw[:, 0] * raw[:, 1]) > 0).astype(np.int64)  # XOR quadrants
        yoh = np.zeros((S.BATCH, S.C_PAD), dtype=np.float32)
        yoh[np.arange(S.BATCH), y] = 1.0
        smask = np.ones(S.BATCH, dtype=np.float32)
        cmask = np.zeros(S.C_PAD, dtype=np.float32)
        cmask[:2] = 1.0
        w1 = (rng.normal(0, 0.5, (S.F_PAD, S.HIDDEN))).astype(np.float32)
        b1 = np.zeros(S.HIDDEN, dtype=np.float32)
        w2 = (rng.normal(0, 0.5, (S.HIDDEN, S.C_PAD))).astype(np.float32)
        b2 = np.zeros(S.C_PAD, dtype=np.float32)
        step = jax.jit(mlp_train_step)
        for _ in range(300):
            w1, b1, w2, b2, loss = step(x, yoh, smask, cmask, w1, b1, w2, b2,
                                        jnp.float32(0.3), jnp.float32(1e-5))
        (logits,) = mlp_predict(x, w1, b1, w2, b2, cmask)
        acc = float((np.argmax(np.asarray(logits), axis=1) == y).mean())
        assert acc > 0.9  # logreg cannot do this; the MLP must


class TestEpochScan:
    """The epoch-scan artifacts must equal EPOCH_TILES sequential steps."""

    def _tiles(self, rng, n_live):
        xb = np.zeros((S.EPOCH_TILES, S.BATCH, S.F_PAD), dtype=np.float32)
        yb = np.zeros((S.EPOCH_TILES, S.BATCH, S.C_PAD), dtype=np.float32)
        sb = np.zeros((S.EPOCH_TILES, S.BATCH), dtype=np.float32)
        for t in range(n_live):
            xb[t, :, :6] = rng.normal(0, 1, (S.BATCH, 6)).astype(np.float32)
            cls = rng.integers(0, 2, S.BATCH)
            yb[t, np.arange(S.BATCH), cls] = 1.0
            sb[t, :] = 1.0
        return xb, yb, sb

    def test_logreg_epoch_equals_sequential_steps(self):
        rng = np.random.default_rng(5)
        xb, yb, sb = self._tiles(rng, S.EPOCH_TILES)
        cmask = np.zeros(S.C_PAD, dtype=np.float32)
        cmask[:2] = 1.0
        w0 = rng.normal(0, 0.1, (S.F_PAD, S.C_PAD)).astype(np.float32)
        b0 = np.zeros(S.C_PAD, dtype=np.float32)
        lr, l2 = jnp.float32(0.1), jnp.float32(1e-4)
        we, be, _ = logreg_train_epoch(xb, yb, sb, cmask, w0, b0, lr, l2)
        w, b = w0, b0
        for t in range(S.EPOCH_TILES):
            w, b, _ = logreg_train_step(xb[t], yb[t], sb[t], cmask, w, b, lr, l2)
        np.testing.assert_allclose(np.asarray(we), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(be), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

    def test_padding_tiles_are_noops(self):
        rng = np.random.default_rng(6)
        xb, yb, sb = self._tiles(rng, 3)  # only 3 live tiles
        cmask = np.zeros(S.C_PAD, dtype=np.float32)
        cmask[:2] = 1.0
        w0 = rng.normal(0, 0.1, (S.F_PAD, S.HIDDEN)).astype(np.float32)
        b0 = np.zeros(S.HIDDEN, dtype=np.float32)
        w1 = rng.normal(0, 0.1, (S.HIDDEN, S.C_PAD)).astype(np.float32)
        b1 = np.zeros(S.C_PAD, dtype=np.float32)
        lr, l2 = jnp.float32(0.1), jnp.float32(0.0)
        we = mlp_train_epoch(xb, yb, sb, cmask, w0, b0, w1, b1, lr, l2)
        # sequential over the 3 live tiles only
        p = (w0, b0, w1, b1)
        for t in range(3):
            out = mlp_train_step(xb[t], yb[t], sb[t], cmask, *p, lr, l2)
            p = out[:4]
        for got, want in zip(we[:4], p):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-5)


class TestEntropyGraphs:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_batch_matches_scalar(self, seed):
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, S.K_BINS,
                             size=(S.B_BATCH, S.N_PAD, S.M_PAD)).astype(
                                 np.int32)
        rmask = (rng.uniform(size=(S.B_BATCH, S.N_PAD)) < 0.3).astype(
            np.float32)
        rmask[:, 0] = 1.0  # at least one active row
        cmask = (rng.uniform(size=(S.B_BATCH, S.M_PAD)) < 0.5).astype(
            np.float32)
        cmask[:, 0] = 1.0
        (hb,) = entropy_batch(codes, rmask, cmask)
        for i in range(S.B_BATCH):
            (hs,) = entropy_subset(codes[i], rmask[i], cmask[i])
            assert abs(float(hb[i]) - float(hs)) < 1e-5

    def test_scalar_matches_oracle(self):
        rng = np.random.default_rng(7)
        codes = rng.integers(0, S.K_BINS,
                             size=(S.N_PAD, S.M_PAD)).astype(np.int32)
        rmask = np.zeros(S.N_PAD, dtype=np.float32)
        rmask[:100] = 1.0
        cmask = np.zeros(S.M_PAD, dtype=np.float32)
        cmask[:7] = 1.0
        (h,) = entropy_subset(codes, rmask, cmask)
        ref = dataset_entropy_ref(jnp.asarray(codes), jnp.asarray(rmask),
                                  jnp.asarray(cmask), S.K_BINS)
        assert abs(float(h) - float(ref)) < 1e-5


class TestKmeansGraph:
    def test_lloyd_reduces_inertia(self):
        rng = np.random.default_rng(8)
        pts = np.zeros((S.KM_POINTS, S.KM_DIM), dtype=np.float32)
        pts[:, :2] = np.concatenate([
            rng.normal(0, 1, (S.KM_POINTS // 2, 2)),
            rng.normal(8, 1, (S.KM_POINTS - S.KM_POINTS // 2, 2)),
        ]).astype(np.float32)
        pmask = np.ones(S.KM_POINTS, dtype=np.float32)
        cent = pts[rng.permutation(S.KM_POINTS)[:S.KM_K]].copy()

        def inertia(c):
            d2 = ((pts[:, None, :] - c[None]) ** 2).sum(-1)
            return float(d2.min(axis=1).sum())

        i0 = inertia(cent)
        for _ in range(5):
            cent, assign = kmeans_step(pts, pmask, cent)
            cent = np.asarray(cent)
        assert inertia(cent) < i0


class TestAotLowering:
    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_lowers_to_parseable_hlo_text(self, name):
        fn, arg_specs = SPECS[name]
        text = to_hlo_text(jax.jit(fn).lower(*arg_specs))
        assert "HloModule" in text
        assert "ENTRY" in text
        # 64-bit ids are exactly what xla_extension 0.5.1 rejects — the
        # text format carries no ids, so presence of text is the guarantee;
        # still check it is non-trivial.
        assert len(text) > 500
