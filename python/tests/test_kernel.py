"""L1 correctness: the Pallas entropy kernel vs the pure-jnp oracle and
hand-computed ground truth, including the paper's worked Example 3.5.
hypothesis sweeps shapes / bin counts / masks.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import shapes
from compile.kernels.entropy import column_entropy
from compile.kernels.ref import (column_entropy_ref, dataset_entropy_ref,
                                 kmeans_step_ref)

jax.config.update("jax_platform_name", "cpu")


def np_column_entropy(codes: np.ndarray, rmask: np.ndarray) -> np.ndarray:
    """Third, numpy-only implementation (np.unique based) as ground truth."""
    active = codes[rmask.astype(bool)]
    out = []
    for j in range(codes.shape[1]):
        _, counts = np.unique(active[:, j], return_counts=True)
        p = counts / counts.sum()
        out.append(float(-(p * np.log2(p)).sum()))
    return np.array(out, dtype=np.float32)


def rand_case(rng, n, m, k_bins, frac_active):
    codes = rng.integers(0, k_bins, size=(n, m)).astype(np.int32)
    n_act = max(1, int(round(frac_active * n)))
    rmask = np.zeros(n, dtype=np.float32)
    rmask[rng.permutation(n)[:n_act]] = 1.0
    return codes, rmask


# --------------------------------------------------------------------------
# fixed cases
# --------------------------------------------------------------------------

class TestFixed:
    def test_uniform_two_values_is_one_bit(self):
        codes = np.array([[0], [1]] * 8, dtype=np.int32)
        codes = np.tile(codes, (1, shapes.M_BLK))
        rmask = np.ones(16, dtype=np.float32)
        h = column_entropy(jnp.asarray(codes), jnp.asarray(rmask), k_bins=4)
        np.testing.assert_allclose(np.asarray(h), 1.0, rtol=1e-6)

    def test_constant_column_zero_entropy(self):
        codes = np.zeros((32, shapes.M_BLK), dtype=np.int32)
        rmask = np.ones(32, dtype=np.float32)
        h = column_entropy(jnp.asarray(codes), jnp.asarray(rmask), k_bins=8)
        np.testing.assert_allclose(np.asarray(h), 0.0, atol=1e-7)

    def test_uniform_k_values_is_log2k(self):
        k = 8
        codes = np.arange(64, dtype=np.int32).reshape(64, 1) % k
        codes = np.tile(codes, (1, shapes.M_BLK))
        rmask = np.ones(64, dtype=np.float32)
        h = column_entropy(jnp.asarray(codes), jnp.asarray(rmask), k_bins=16)
        np.testing.assert_allclose(np.asarray(h), math.log2(k), rtol=1e-6)

    def test_row_mask_excludes_rows(self):
        # active rows all hold 0; masked rows hold 1..k — entropy must be 0
        codes = np.zeros((32, shapes.M_BLK), dtype=np.int32)
        codes[16:] = 3
        rmask = np.zeros(32, dtype=np.float32)
        rmask[:16] = 1.0
        h = column_entropy(jnp.asarray(codes), jnp.asarray(rmask), k_bins=8)
        np.testing.assert_allclose(np.asarray(h), 0.0, atol=1e-7)

    def test_paper_example_3_5_full_dataset(self):
        """Table 1 flight-review dataset: H(D) = (2.65+1+1+1.4+0.97)/5."""
        age = [25, 62, 25, 41, 27, 41, 20, 25, 13, 52]
        gender = [1, 1, 0, 0, 1, 1, 0, 0, 0, 1]
        dist = [460] * 5 + [1061] * 5
        delay = [18, 0, 40, 0, 0, 0, 0, 51, 0, 0]
        target = [1, 0, 1, 1, 1, 0, 0, 0, 1, 1]
        cols = [age, gender, dist, delay, target]
        # encode values to codes (any bijection works for entropy)
        codes = np.zeros((10, shapes.M_BLK), dtype=np.int32)
        for j, col in enumerate(cols):
            uniq = {v: i for i, v in enumerate(dict.fromkeys(col))}
            codes[:, j] = [uniq[v] for v in col]
        rmask = np.ones(10, dtype=np.float32)
        h = np.asarray(column_entropy(jnp.asarray(codes), jnp.asarray(rmask),
                                      k_bins=16))
        np.testing.assert_allclose(h[:5], [2.646, 1.0, 1.0, 1.357, 0.971],
                                   atol=5e-3)
        cmask = np.zeros(shapes.M_BLK, dtype=np.float32)
        cmask[:5] = 1.0
        hd = dataset_entropy_ref(jnp.asarray(codes), jnp.asarray(rmask),
                                 jnp.asarray(cmask), 16)
        assert abs(float(hd) - 1.395) < 5e-3

    def test_paper_example_3_5_green_subset(self):
        """d_green = rows (1,2,3,6,8), cols (Age, Delay, target): H ~ 1.42."""
        age = [25, 62, 25, 41, 27, 41, 20, 25, 13, 52]
        delay = [18, 0, 40, 0, 0, 0, 0, 51, 0, 0]
        target = [1, 0, 1, 1, 1, 0, 0, 0, 1, 1]
        rows = [0, 1, 2, 5, 7]
        cols = [age, delay, target]
        codes = np.zeros((5, shapes.M_BLK), dtype=np.int32)
        for j, col in enumerate(cols):
            sub = [col[i] for i in rows]
            uniq = {v: i for i, v in enumerate(dict.fromkeys(sub))}
            codes[:, j] = [uniq[v] for v in sub]
        rmask = np.ones(5, dtype=np.float32)
        h = np.asarray(column_entropy(jnp.asarray(codes), jnp.asarray(rmask),
                                      k_bins=16))
        # paper: (1.37 + 1.92 + 0.97) / 3 = 1.42
        np.testing.assert_allclose(h[:3], [1.371, 1.922, 0.971], atol=5e-3)
        assert abs(float(h[:3].mean()) - 1.42) < 5e-3


# --------------------------------------------------------------------------
# kernel vs oracle vs numpy — hypothesis sweep
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 200),
    mb=st.integers(1, 4),
    k_bins=st.sampled_from([2, 4, 16, 64]),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_and_numpy(n, mb, k_bins, frac, seed):
    rng = np.random.default_rng(seed)
    m = mb * shapes.M_BLK
    codes, rmask = rand_case(rng, n, m, k_bins, frac)
    got = np.asarray(column_entropy(jnp.asarray(codes), jnp.asarray(rmask),
                                    k_bins=k_bins))
    ref = np.asarray(column_entropy_ref(jnp.asarray(codes),
                                        jnp.asarray(rmask), k_bins))
    npy = np_column_entropy(codes, rmask)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got, npy, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 100),
    k_bins=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_entropy_invariant_under_code_relabeling(n, k_bins, seed):
    """Entropy depends only on the frequency profile, not code identity."""
    rng = np.random.default_rng(seed)
    m = shapes.M_BLK
    codes, rmask = rand_case(rng, n, m, k_bins, 1.0)
    perm = rng.permutation(k_bins).astype(np.int32)
    relabeled = perm[codes]
    h1 = np.asarray(column_entropy(jnp.asarray(codes), jnp.asarray(rmask),
                                   k_bins=k_bins))
    h2 = np.asarray(column_entropy(jnp.asarray(relabeled),
                                   jnp.asarray(rmask), k_bins=k_bins))
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 100), seed=st.integers(0, 2**31 - 1))
def test_entropy_bounded_by_log2_support(n, seed):
    rng = np.random.default_rng(seed)
    codes, rmask = rand_case(rng, n, shapes.M_BLK, 16, 1.0)
    h = np.asarray(column_entropy(jnp.asarray(codes), jnp.asarray(rmask),
                                  k_bins=16))
    n_act = int(rmask.sum())
    assert (h >= -1e-6).all()
    assert (h <= math.log2(max(2, min(16, n_act))) + 1e-5).all()


# --------------------------------------------------------------------------
# kmeans oracle sanity (the artifact graph reuses the same formula)
# --------------------------------------------------------------------------

class TestKmeansRef:
    def test_converged_fixture(self):
        pts = np.array([[0.0, 0.0], [0.0, 1.0], [10.0, 10.0], [10.0, 11.0]],
                       dtype=np.float32)
        cent = np.array([[0.0, 0.5], [10.0, 10.5]], dtype=np.float32)
        pmask = np.ones(4, dtype=np.float32)
        new_c, assign = kmeans_step_ref(jnp.asarray(pts), jnp.asarray(pmask),
                                        jnp.asarray(cent))
        np.testing.assert_allclose(np.asarray(new_c), cent, atol=1e-6)
        assert list(np.asarray(assign)) == [0, 0, 1, 1]

    def test_masked_points_do_not_pull(self):
        pts = np.array([[0.0, 0.0], [100.0, 100.0]], dtype=np.float32)
        cent = np.array([[0.0, 0.0], [50.0, 50.0]], dtype=np.float32)
        pmask = np.array([1.0, 0.0], dtype=np.float32)
        new_c, _ = kmeans_step_ref(jnp.asarray(pts), jnp.asarray(pmask),
                                   jnp.asarray(cent))
        # centroid 1 has no active points -> unchanged
        np.testing.assert_allclose(np.asarray(new_c)[1], cent[1], atol=1e-6)
