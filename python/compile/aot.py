"""AOT driver: lower every L2 graph in model.SPECS to HLO *text* and write
artifacts/<name>.hlo.txt plus a manifest the rust runtime can sanity-check.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True; the rust side unwraps with
Literal::to_tuple().

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import shapes
from compile.model import SPECS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    dt = {"float32": "f32", "int32": "i32"}[str(s.dtype)]
    return f"{dt}[{','.join(str(d) for d in s.shape)}]"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(SPECS) if args.only is None else args.only.split(",")
    manifest = []
    for name in names:
        fn, arg_specs = SPECS[name]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_out = len(fn(*[jax.ShapeDtypeStruct(s.shape, s.dtype)
                         for s in arg_specs])) if False else None
        manifest.append((name, [_spec_str(s) for s in arg_specs]))
        print(f"wrote {path} ({len(text)} chars)")

    # tiny hand-rolled manifest (no json dep needed on the rust side)
    man_path = os.path.join(args.out_dir, "manifest.txt")
    with open(man_path, "w") as f:
        f.write(f"# artifact manifest — shapes {shapes.N_PAD}x{shapes.M_PAD}"
                f" K={shapes.K_BINS} B={shapes.B_BATCH} F={shapes.F_PAD}"
                f" C={shapes.C_PAD} BATCH={shapes.BATCH}"
                f" H={shapes.HIDDEN}\n")
        for name, specs in manifest:
            f.write(f"{name}: {' '.join(specs)}\n")
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
