"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
ground truth. pytest asserts kernel == ref (allclose) across a hypothesis
sweep of shapes / masks / bin counts; the rust native path is additionally
pinned to the paper's worked Example 3.5 in rust unit tests.
"""

import jax
import jax.numpy as jnp


def column_entropy_ref(codes, rmask, k_bins: int):
    """Per-column Shannon entropy (bits) over active rows.

    codes: (n, m) int32 in [0, k_bins); rmask: (n,) float32 0/1.
    Returns (m,) float32.
    """
    rmask = rmask.astype(jnp.float32)
    n_act = jnp.maximum(jnp.sum(rmask), 1.0)
    onehot = jax.nn.one_hot(codes, k_bins, dtype=jnp.float32)  # (n, m, K)
    counts = jnp.einsum("nmk,n->mk", onehot, rmask)            # (m, K)
    p = counts / n_act
    terms = jnp.where(p > 0.0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    return -jnp.sum(terms, axis=1)


def dataset_entropy_ref(codes, rmask, cmask, k_bins: int):
    """Paper Def. 3.4 (sign-corrected): mean per-column entropy, masked."""
    h = column_entropy_ref(codes, rmask, k_bins)
    cmask = cmask.astype(jnp.float32)
    return jnp.sum(h * cmask) / jnp.maximum(jnp.sum(cmask), 1.0)


def kmeans_step_ref(points, pmask, centroids):
    """One Lloyd iteration: assign active points, recompute centroids."""
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32) * pmask[:, None]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ points
    new_c = jnp.where(counts[:, None] > 0,
                      sums / jnp.maximum(counts, 1.0)[:, None], centroids)
    return new_c, assign.astype(jnp.int32)
