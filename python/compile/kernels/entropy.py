"""L1 Pallas kernel: per-column Shannon entropy of an integer-code tile.

This is the hot spot of Gen-DST: every GA candidate's fitness is
``|H(D[r,c]) - H(D)|`` where H is the mean per-column entropy of the value
frequency distribution (paper Def. 3.4, sign-corrected to standard Shannon
entropy as in the paper's own Example 3.5).

Kernel contract
---------------
    codes : (n, m) int32, values in [0, K_BINS); padded rows hold 0
    rmask : (n, 1) float32, 1.0 for active rows, 0.0 for padding
    out   : (1, m) float32, per-column entropy in bits over active rows

The column mask / mean over columns is applied by the L2 graph (model.py) —
keeping the kernel a pure per-column primitive lets the same artifact serve
both the subset-fitness path and the full-dataset H(D) path.

TPU mapping (DESIGN.md §Hardware-Adaptation): values are pre-binned to
K_BINS codes at ingest, so the per-column distribution is a dense K-slot
histogram. The kernel walks the K bins with a fori_loop; each step is a
masked compare + reduce over the (n, M_BLK) VMEM tile — on real TPU this is
a VPU reduction per bin with the tile resident in VMEM (n*M_BLK*4B = 32 KiB
per block at n=1024, M_BLK=8, well under VMEM). interpret=True is mandatory
here: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import shapes


def _entropy_kernel(codes_ref, rmask_ref, out_ref, *, k_bins: int):
    codes = codes_ref[...]            # (n, mblk) int32
    rmask = rmask_ref[...]            # (n, 1) float32
    n_act = jnp.maximum(jnp.sum(rmask), 1.0)

    def body(k, acc):
        # count of code k per column, over active rows only
        cnt = jnp.sum(jnp.where(codes == k, 1.0, 0.0) * rmask, axis=0)
        p = cnt / n_act
        # 0 * log(0) := 0
        term = jnp.where(p > 0.0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
        return acc - term

    mblk = codes.shape[1]
    h = jax.lax.fori_loop(0, k_bins, body, jnp.zeros((mblk,), jnp.float32))
    out_ref[...] = h.reshape(1, mblk)


def column_entropy(codes, rmask, *, k_bins: int = shapes.K_BINS,
                   m_blk: int = shapes.M_BLK):
    """Per-column entropy (bits) of ``codes`` over rows where rmask == 1.

    codes: (n, m) int32 with m % m_blk == 0; rmask: (n,) float32.
    Returns (m,) float32.
    """
    n, m = codes.shape
    assert m % m_blk == 0, f"m={m} must be a multiple of m_blk={m_blk}"
    rmask2 = rmask.reshape(n, 1).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_entropy_kernel, k_bins=k_bins),
        grid=(m // m_blk,),
        in_specs=[
            pl.BlockSpec((n, m_blk), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_blk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(codes, rmask2)
    return out.reshape(m)
