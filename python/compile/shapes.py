"""Fixed AOT artifact shapes, shared between the python compile path and the
rust runtime (mirrored in ``rust/src/runtime/shapes.rs`` — keep in sync).

Every artifact is compiled once for these padded shapes; the rust side
zero-pads real data and passes row/col/sample masks. Sizing rationale (see
DESIGN.md §6): n = sqrt(N) <= 1000 for every Table-2 dataset (max N = 1M),
m = ceil(0.25 * 123) = 31 for the widest dataset, so (1024, 32) covers all
paper workloads with a single artifact.
"""

# --- entropy / Gen-DST fitness -------------------------------------------
N_PAD = 1024      # max subset rows (sqrt(1M) = 1000 rounded up to a tile)
M_PAD = 32        # max subset columns (0.25 * 123 = 31 rounded up)
K_BINS = 64       # per-column value codes (quantile binning at ingest)
B_BATCH = 16      # GA candidates evaluated per PJRT call
M_BLK = 8         # pallas column-block (VMEM tile width)

# --- model training (logreg / mlp) ----------------------------------------
F_PAD = 128       # feature dim after padding (widest dataset: 123 columns)
C_PAD = 16        # class dim after padding (max classes in Table 2: 10)
BATCH = 256       # training mini-batch rows
HIDDEN = 64       # MLP hidden width
EPOCH_TILES = 16  # mini-batches scanned inside one train_epoch call —
                  # one PJRT call trains on EPOCH_TILES*BATCH = 4096 rows
                  # (order-of-magnitude fewer host<->XLA boundary
                  # crossings than per-batch stepping; see §Perf)

# --- k-means baseline ------------------------------------------------------
KM_POINTS = 1024  # points per assignment call
KM_DIM = 32       # point feature dim (column space padded)
KM_K = 32         # max centroids
