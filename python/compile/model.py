"""L2: the jitted JAX compute graphs that get AOT-lowered to HLO text.

Each public function here is one artifact (see aot.py's REGISTRY). All
shapes are the fixed padded tiles from shapes.py; the rust runtime
zero-pads real data and supplies masks. Every function returns a tuple —
the lowering uses return_tuple=True, and the rust side unwraps with
Literal::to_tuple().

Functions fall into three groups:
  * entropy_*      — Gen-DST fitness (calls the L1 Pallas kernel)
  * logreg_* mlp_* — model-zoo train/predict steps (softmax CE, SGD + L2)
  * kmeans_step    — Lloyd iteration for the KM baseline
"""

import jax
import jax.numpy as jnp

from compile import shapes
from compile.kernels.entropy import column_entropy


# --------------------------------------------------------------------------
# entropy / Gen-DST fitness
# --------------------------------------------------------------------------

def _entropy_scalar(codes, rmask, cmask):
    h = column_entropy(codes, rmask)                      # (m,)
    cmask = cmask.astype(jnp.float32)
    return jnp.sum(h * cmask) / jnp.maximum(jnp.sum(cmask), 1.0)


def entropy_subset(codes, rmask, cmask):
    """Masked mean column entropy of one (N_PAD, M_PAD) code tile.

    codes (N_PAD, M_PAD) i32; rmask (N_PAD,) f32; cmask (M_PAD,) f32.
    Returns (H,) — scalar f32.
    """
    return (_entropy_scalar(codes, rmask, cmask),)


def entropy_batch(codes, rmask, cmask):
    """Fitness pre-image for a GA mini-batch: B candidates per PJRT call.

    codes (B_BATCH, N_PAD, M_PAD) i32; rmask (B, N_PAD); cmask (B, M_PAD).
    Returns (H,) with H (B_BATCH,) f32.
    """
    h = jax.lax.map(lambda t: _entropy_scalar(*t), (codes, rmask, cmask))
    return (h,)


def entropy_columns(codes, rmask):
    """Per-column entropies of a full tile — used for H(D) column profiles
    (information-gain style diagnostics and the fig4 sweeps).

    codes (N_PAD, M_PAD) i32; rmask (N_PAD,) f32. Returns ((M_PAD,) f32,).
    """
    return (column_entropy(codes, rmask),)


# --------------------------------------------------------------------------
# logistic regression (softmax) — train step + predict
# --------------------------------------------------------------------------

def _ce_loss(logits, yoh, smask, cmask):
    # mask padded classes to -1e9 so they get ~0 probability mass
    logits = logits + (cmask - 1.0) * 1e9
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_row = -jnp.sum(yoh * logp, axis=-1) * smask
    n = jnp.maximum(jnp.sum(smask), 1.0)
    return jnp.sum(per_row) / n


def logreg_train_step(x, yoh, smask, cmask, w, b, lr, l2):
    """One full-batch SGD step of softmax regression.

    x (BATCH, F_PAD) f32; yoh (BATCH, C_PAD) f32 one-hot; smask (BATCH,);
    cmask (C_PAD,); w (F_PAD, C_PAD); b (C_PAD,); lr, l2 scalars.
    Returns (w', b', loss).
    """
    def loss_fn(params):
        w_, b_ = params
        logits = x @ w_ + b_
        data = _ce_loss(logits, yoh, smask, cmask)
        reg = 0.5 * l2 * jnp.sum(w_ * w_)
        return data + reg

    loss, grads = jax.value_and_grad(loss_fn)((w, b))
    gw, gb = grads
    return (w - lr * gw, b - lr * gb, loss)


def logreg_train_epoch(xb, yb, sb, cmask, w, b, lr, l2):
    """EPOCH_TILES SGD steps in ONE call: scan over pre-batched tiles.

    xb (EPOCH_TILES, BATCH, F_PAD); yb (EPOCH_TILES, BATCH, C_PAD);
    sb (EPOCH_TILES, BATCH) sample masks (all-zero tiles are skipped via
    masking); cmask (C_PAD,); w, b params; lr, l2 scalars.
    Returns (w', b', mean_loss). Replaces EPOCH_TILES host<->XLA round
    trips with one — the dominant cost of the per-batch path (§Perf).
    """
    def step(carry, tile):
        w_, b_ = carry
        x, yoh, smask = tile
        def loss_fn(params):
            w2, b2 = params
            logits = x @ w2 + b2
            data = _ce_loss(logits, yoh, smask, cmask)
            return data + 0.5 * l2 * jnp.sum(w2 * w2)
        loss, grads = jax.value_and_grad(loss_fn)((w_, b_))
        gw, gb = grads
        # all-padding tiles (sum smask == 0) must be a no-op
        live = (jnp.sum(smask) > 0.0).astype(jnp.float32)
        return (w_ - lr * live * gw, b_ - lr * live * gb), loss * live

    (w_f, b_f), losses = jax.lax.scan(step, (w, b), (xb, yb, sb))
    n_live = jnp.maximum(jnp.sum((jnp.sum(sb, axis=1) > 0.0)), 1.0)
    return (w_f, b_f, jnp.sum(losses) / n_live)


def logreg_predict(x, w, b, cmask):
    """Masked logits for a batch. Returns ((BATCH, C_PAD) f32,)."""
    logits = x @ w + b + (cmask - 1.0) * 1e9
    return (logits,)


# --------------------------------------------------------------------------
# one-hidden-layer MLP — train step + predict
# --------------------------------------------------------------------------

def mlp_train_step(x, yoh, smask, cmask, w1, b1, w2, b2, lr, l2):
    """One full-batch SGD step of a tanh MLP (F_PAD -> HIDDEN -> C_PAD).

    Returns (w1', b1', w2', b2', loss).
    """
    def loss_fn(params):
        w1_, b1_, w2_, b2_ = params
        h = jnp.tanh(x @ w1_ + b1_)
        logits = h @ w2_ + b2_
        data = _ce_loss(logits, yoh, smask, cmask)
        reg = 0.5 * l2 * (jnp.sum(w1_ * w1_) + jnp.sum(w2_ * w2_))
        return data + reg

    loss, grads = jax.value_and_grad(loss_fn)((w1, b1, w2, b2))
    g1, gb1, g2, gb2 = grads
    return (w1 - lr * g1, b1 - lr * gb1, w2 - lr * g2, b2 - lr * gb2, loss)


def mlp_train_epoch(xb, yb, sb, cmask, w1, b1, w2, b2, lr, l2):
    """MLP twin of logreg_train_epoch: EPOCH_TILES steps per call."""
    def step(carry, tile):
        w1_, b1_, w2_, b2_ = carry
        x, yoh, smask = tile
        def loss_fn(params):
            a1, c1, a2, c2 = params
            h = jnp.tanh(x @ a1 + c1)
            logits = h @ a2 + c2
            data = _ce_loss(logits, yoh, smask, cmask)
            reg = 0.5 * l2 * (jnp.sum(a1 * a1) + jnp.sum(a2 * a2))
            return data + reg
        loss, grads = jax.value_and_grad(loss_fn)((w1_, b1_, w2_, b2_))
        g1, gb1, g2, gb2 = grads
        live = (jnp.sum(smask) > 0.0).astype(jnp.float32)
        new = (
            w1_ - lr * live * g1,
            b1_ - lr * live * gb1,
            w2_ - lr * live * g2,
            b2_ - lr * live * gb2,
        )
        return new, loss * live

    carry, losses = jax.lax.scan(step, (w1, b1, w2, b2), (xb, yb, sb))
    w1_f, b1_f, w2_f, b2_f = carry
    n_live = jnp.maximum(jnp.sum((jnp.sum(sb, axis=1) > 0.0)), 1.0)
    return (w1_f, b1_f, w2_f, b2_f, jnp.sum(losses) / n_live)


def mlp_predict(x, w1, b1, w2, b2, cmask):
    """Masked logits. Returns ((BATCH, C_PAD) f32,)."""
    h = jnp.tanh(x @ w1 + b1)
    logits = h @ w2 + b2 + (cmask - 1.0) * 1e9
    return (logits,)


# --------------------------------------------------------------------------
# k-means (Lloyd) step — KM baseline substrate
# --------------------------------------------------------------------------

def kmeans_step(points, pmask, centroids):
    """One Lloyd iteration on padded points.

    points (KM_POINTS, KM_DIM) f32; pmask (KM_POINTS,) f32;
    centroids (KM_K, KM_DIM) f32. Padded points must be pushed far away by
    the caller (or masked here): we add a large penalty so they never pull
    centroids. Returns (new_centroids, assignments i32).
    """
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(assign, centroids.shape[0],
                            dtype=jnp.float32) * pmask[:, None]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ points
    new_c = jnp.where(counts[:, None] > 0.0,
                      sums / jnp.maximum(counts, 1.0)[:, None], centroids)
    return (new_c, assign.astype(jnp.int32))


# --------------------------------------------------------------------------
# example-arg specs (shared by aot.py and the pytest suite)
# --------------------------------------------------------------------------

def _f(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def _i(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


S = shapes

SPECS = {
    "entropy_subset": (entropy_subset,
                       [_i(S.N_PAD, S.M_PAD), _f(S.N_PAD), _f(S.M_PAD)]),
    "entropy_batch": (entropy_batch,
                      [_i(S.B_BATCH, S.N_PAD, S.M_PAD),
                       _f(S.B_BATCH, S.N_PAD), _f(S.B_BATCH, S.M_PAD)]),
    "entropy_columns": (entropy_columns, [_i(S.N_PAD, S.M_PAD), _f(S.N_PAD)]),
    "logreg_train_step": (logreg_train_step,
                          [_f(S.BATCH, S.F_PAD), _f(S.BATCH, S.C_PAD),
                           _f(S.BATCH), _f(S.C_PAD),
                           _f(S.F_PAD, S.C_PAD), _f(S.C_PAD), _f(), _f()]),
    "logreg_train_epoch": (logreg_train_epoch,
                           [_f(S.EPOCH_TILES, S.BATCH, S.F_PAD),
                            _f(S.EPOCH_TILES, S.BATCH, S.C_PAD),
                            _f(S.EPOCH_TILES, S.BATCH), _f(S.C_PAD),
                            _f(S.F_PAD, S.C_PAD), _f(S.C_PAD), _f(), _f()]),
    "logreg_predict": (logreg_predict,
                       [_f(S.BATCH, S.F_PAD), _f(S.F_PAD, S.C_PAD),
                        _f(S.C_PAD), _f(S.C_PAD)]),
    "mlp_train_step": (mlp_train_step,
                       [_f(S.BATCH, S.F_PAD), _f(S.BATCH, S.C_PAD),
                        _f(S.BATCH), _f(S.C_PAD),
                        _f(S.F_PAD, S.HIDDEN), _f(S.HIDDEN),
                        _f(S.HIDDEN, S.C_PAD), _f(S.C_PAD), _f(), _f()]),
    "mlp_train_epoch": (mlp_train_epoch,
                        [_f(S.EPOCH_TILES, S.BATCH, S.F_PAD),
                         _f(S.EPOCH_TILES, S.BATCH, S.C_PAD),
                         _f(S.EPOCH_TILES, S.BATCH), _f(S.C_PAD),
                         _f(S.F_PAD, S.HIDDEN), _f(S.HIDDEN),
                         _f(S.HIDDEN, S.C_PAD), _f(S.C_PAD), _f(), _f()]),
    "mlp_predict": (mlp_predict,
                    [_f(S.BATCH, S.F_PAD), _f(S.F_PAD, S.HIDDEN),
                     _f(S.HIDDEN), _f(S.HIDDEN, S.C_PAD), _f(S.C_PAD),
                     _f(S.C_PAD)]),
    "kmeans_step": (kmeans_step,
                    [_f(S.KM_POINTS, S.KM_DIM), _f(S.KM_POINTS),
                     _f(S.KM_K, S.KM_DIM)]),
}
